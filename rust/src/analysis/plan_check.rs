//! Plan-level routing: dry-compile every artifact's [`StepPlan`] slot
//! assignment against the manifest, using the *same* classifier the
//! coordinator compiles real plans with (`coordinator::session::
//! classify_input`/`classify_output`) — so a green check proves the session
//! would route every slot, at check time instead of step time.
//!
//! Per artifact this proves: every input has exactly one [`SlotSrc`] under
//! its kind's routing, every named store slot references a real parameter
//! with the declared shape and dtype, write-back outputs have a matching
//! same-named input, the frozen and mutated slot sets are disjoint (the bug
//! class PR 4's enum routing exists to prevent), and the positional output
//! contracts of the eval/calibrate/grad_scores/fwd drivers hold.

use crate::coordinator::session::{
    classify_input, classify_output, OutSink, Routing, SlotSrc,
};
use crate::runtime::{ArtifactSpec, Dtype, IoSpec, Manifest, ModelConfig};

use super::finding::Finding;

/// The positional outputs train drivers read; anything else that classifies
/// as `Skip` in a train artifact is silently dropped state.
const TRAIN_POSITIONAL: [&str; 4] = ["loss", "n_correct", "loss_sum", "top5_correct"];

pub(crate) fn check_plans(m: &Manifest) -> Vec<Finding> {
    let mut fs = Vec::new();
    for a in m.artifacts.values() {
        let cfg = match m.configs.get(&a.config) {
            Some(c) => c,
            // dangling config refs are manifest-level errors; plan checks
            // only run on walk-clean manifests, so this is unreachable in
            // practice but kept total
            None => continue,
        };
        check_dup_io(&mut fs, a);
        let routing = match a.kind.as_str() {
            "train_adam" | "train_sgd" => Routing::Dense,
            "eval" => Routing::DenseEval,
            "lora_train" | "lora_eval" => Routing::Lora,
            "vpt_train" | "vpt_eval" | "adapter_train" | "adapter_eval" => Routing::Aux,
            "calibrate" => Routing::Calibrate,
            "grad_scores" => Routing::GradScores,
            "fwd" => {
                check_fwd(&mut fs, m, cfg, a);
                continue;
            }
            other => {
                fs.push(Finding::warning(
                    "plan.unknown-kind",
                    format!("artifacts.{}", a.name),
                    format!("kind {other:?} matches no session routing; the coordinator will never execute it"),
                ));
                continue;
            }
        };
        check_routed(&mut fs, m, cfg, a, routing);
    }
    fs
}

fn check_dup_io(fs: &mut Vec<Finding>, a: &ArtifactSpec) {
    for (key, specs) in [("inputs", &a.inputs), ("outputs", &a.outputs)] {
        let mut seen = std::collections::BTreeSet::new();
        for (i, io) in specs.iter().enumerate() {
            if !seen.insert(io.name.as_str()) {
                fs.push(Finding::error(
                    "plan.dup-io",
                    format!("artifacts.{}.{key}[{i}]", a.name),
                    format!("duplicate {key} name {:?} — by-name resolution (input_index/output_index) would silently bind the first", io.name),
                ));
            }
        }
    }
}

/// Shared check for every artifact kind the session executes via StepPlan.
fn check_routed(
    fs: &mut Vec<Finding>,
    m: &Manifest,
    cfg: &ModelConfig,
    a: &ArtifactSpec,
    routing: Routing,
) {
    let mut frozen_names: Vec<&str> = Vec::new();
    for (i, io) in a.inputs.iter().enumerate() {
        let span = format!("artifacts.{}.inputs[{i}]", a.name);
        let (src, frozen) = match classify_input(routing, &io.name) {
            Ok(v) => v,
            Err(e) => {
                fs.push(Finding::error(
                    "plan.unroutable-input",
                    span,
                    format!("input {:?} has no slot source under {routing:?} routing: {e:#}", io.name),
                ));
                continue;
            }
        };
        if frozen {
            frozen_names.push(&io.name);
        }
        match &src {
            SlotSrc::Param(p)
            | SlotSrc::AdamM(p)
            | SlotSrc::AdamV(p)
            | SlotSrc::Mom(p) => {
                check_param_slot(fs, cfg, io, p, &span, false);
            }
            SlotSrc::Mask(p) => check_param_slot(fs, cfg, io, p, &span, true),
            SlotSrc::Images => {
                let want = vec![m.batch, cfg.image_size, cfg.image_size, cfg.channels];
                expect_shape(fs, io, &want, &span);
                expect_dtype(fs, io, Dtype::F32, &span);
            }
            SlotSrc::Labels => {
                expect_shape(fs, io, &[m.batch], &span);
                expect_dtype(fs, io, Dtype::I32, &span);
            }
            SlotSrc::Step | SlotSrc::Lr | SlotSrc::Wd => {
                expect_shape(fs, io, &[], &span);
                expect_dtype(fs, io, Dtype::F32, &span);
            }
            SlotSrc::State(name) => {
                expect_dtype(fs, io, Dtype::F32, &span);
                if routing == Routing::Lora {
                    check_lora_state_slot(fs, cfg, io, name, &span);
                }
                // Aux state (prompt / adapter stacks / their moments) is a
                // free-form named map; shapes are owned by the graph
            }
        }
    }

    let mut written: Vec<&str> = Vec::new();
    for (i, io) in a.outputs.iter().enumerate() {
        let span = format!("artifacts.{}.outputs[{i}]", a.name);
        match classify_output(routing, &io.name) {
            OutSink::Loss | OutSink::NCorrect => {
                expect_shape(fs, io, &[], &span);
            }
            OutSink::Param(_)
            | OutSink::AdamM(_)
            | OutSink::AdamV(_)
            | OutSink::Mom(_)
            | OutSink::State(_) => {
                written.push(&io.name);
                // a write-back sink moves the output tensor into the slot
                // the same-named input was drawn from; without that input
                // the artifact "updates" state the session never reads
                match a.inputs.iter().find(|inp| inp.name == io.name) {
                    None => fs.push(Finding::error(
                        "plan.sink-no-source",
                        span,
                        format!("output {:?} writes back to a slot with no same-named input", io.name),
                    )),
                    Some(inp) if inp.shape != io.shape || inp.dtype != io.dtype => {
                        fs.push(Finding::error(
                            "plan.shape-mismatch",
                            span,
                            format!(
                                "write-back {:?}: output {:?} {:?} vs input {:?} {:?}",
                                io.name, io.shape, io.dtype, inp.shape, inp.dtype
                            ),
                        ));
                    }
                    Some(_) => {}
                }
            }
            OutSink::Skip => {
                let is_train = a.kind.ends_with("_train")
                    || matches!(a.kind.as_str(), "train_adam" | "train_sgd");
                if is_train && !TRAIN_POSITIONAL.contains(&io.name.as_str()) {
                    fs.push(Finding::warning(
                        "plan.ignored-output",
                        span,
                        format!("train output {:?} classifies as Skip — the session will drop it every step", io.name),
                    ));
                }
            }
        }
    }

    // frozen-vs-mutable disjointness: a slot frozen as a device literal
    // that an output then writes back would silently diverge from the
    // prepared copy on the next step
    for w in &written {
        if frozen_names.contains(w) {
            fs.push(Finding::error(
                "plan.frozen-mutated",
                format!("artifacts.{}", a.name),
                format!("slot {w:?} is frozen under {routing:?} routing but a graph output writes it back"),
            ));
        }
    }

    match routing {
        Routing::DenseEval => check_eval_outputs(fs, a),
        Routing::Lora | Routing::Aux if a.kind.ends_with("_eval") => {
            check_eval_outputs(fs, a)
        }
        Routing::Calibrate => check_calibrate_outputs(fs, cfg, a),
        Routing::GradScores => check_grad_outputs(fs, cfg, a),
        _ => {}
    }
}

/// `param:P` / `mask:P` / `adam_m:P` / `adam_v:P` / `mom:P` must name a
/// real param of the artifact's config, with the param's exact shape, in
/// f32.
fn check_param_slot(
    fs: &mut Vec<Finding>,
    cfg: &ModelConfig,
    io: &IoSpec,
    p: &str,
    span: &str,
    is_mask: bool,
) {
    let spec = match cfg.params.iter().find(|ps| ps.name == p) {
        Some(s) => s,
        None => {
            fs.push(Finding::error(
                "plan.unknown-param",
                span.to_string(),
                format!("input {:?} references param {p:?}, absent from config {:?}", io.name, cfg.name),
            ));
            return;
        }
    };
    if io.shape != spec.shape {
        fs.push(Finding::error(
            "plan.shape-mismatch",
            span.to_string(),
            format!("input {:?} shape {:?} vs param {p:?} shape {:?}", io.name, io.shape, spec.shape),
        ));
    }
    expect_dtype(fs, io, Dtype::F32, span);
    if is_mask && !spec.masked {
        fs.push(Finding::warning(
            "plan.mask-unmasked",
            span.to_string(),
            format!("mask slot for param {p:?}, which the config declares masked=false — the allocator builds no mask for it"),
        ));
    }
}

/// LoRA state slots (`lora_b:T` etc.) must target a declared 2-D LoRA
/// target and carry factor shapes consistent with `cfg.lora_rank`.
fn check_lora_state_slot(
    fs: &mut Vec<Finding>,
    cfg: &ModelConfig,
    io: &IoSpec,
    name: &str,
    span: &str,
) {
    let (prefix, target) = match name.split_once(':') {
        Some(v) => v,
        None => return,
    };
    let spec = match cfg.params.iter().find(|ps| ps.name == target) {
        Some(s) => s,
        None => {
            fs.push(Finding::error(
                "plan.unknown-param",
                span.to_string(),
                format!("lora state {name:?} targets param {target:?}, absent from config {:?}", cfg.name),
            ));
            return;
        }
    };
    if !cfg.lora_targets.iter().any(|t| t == target) {
        fs.push(Finding::warning(
            "plan.lora-target-undeclared",
            span.to_string(),
            format!("lora state {name:?} targets {target:?}, which is not in lora_targets"),
        ));
    }
    if spec.shape.len() != 2 {
        fs.push(Finding::error(
            "plan.shape-mismatch",
            span.to_string(),
            format!("lora target {target:?} is rank-{}, not a 2-D weight", spec.shape.len()),
        ));
        return;
    }
    let (d_in, d_out, r) = (spec.shape[0], spec.shape[1], cfg.lora_rank);
    // B-side factors/moments are (d_in, r); A-side are (r, d_out)
    let want = match prefix {
        "lora_b" | "mb" | "vb" => vec![d_in, r],
        "lora_a" | "ma" | "va" => vec![r, d_out],
        _ => return,
    };
    expect_shape(fs, io, &want, span);
}

/// All eval artifacts (every family) are read through `EvalPlan`, which
/// resolves these three outputs by name.
fn check_eval_outputs(fs: &mut Vec<Finding>, a: &ArtifactSpec) {
    for name in ["loss_sum", "n_correct", "top5_correct"] {
        if !a.outputs.iter().any(|o| o.name == name) {
            fs.push(Finding::error(
                "plan.missing-output",
                format!("artifacts.{}", a.name),
                format!("eval artifact lacks output {name:?} (EvalPlan resolves it by name)"),
            ));
        }
    }
}

/// Calibrate outputs are `stat:S` accumulators: each `S` must be a stat
/// some param declares, and every declared stat should be produced.
fn check_calibrate_outputs(fs: &mut Vec<Finding>, cfg: &ModelConfig, a: &ArtifactSpec) {
    let declared: std::collections::BTreeSet<&str> =
        cfg.params.iter().filter_map(|p| p.stat.as_deref()).collect();
    let mut produced = std::collections::BTreeSet::new();
    for (i, o) in a.outputs.iter().enumerate() {
        let span = format!("artifacts.{}.outputs[{i}]", a.name);
        let stat = match o.name.strip_prefix("stat:") {
            Some(s) => s,
            None => {
                fs.push(Finding::error(
                    "plan.bad-output",
                    span,
                    format!("calibrate output {:?} is not a stat:* accumulator", o.name),
                ));
                continue;
            }
        };
        produced.insert(stat);
        if !declared.contains(stat) {
            fs.push(Finding::error(
                "plan.unknown-stat",
                span.clone(),
                format!("calibrate output {stat:?} matches no param's stat in config {:?}", cfg.name),
            ));
        }
        // StatAccumulator sizes itself on shape[0]
        if o.shape.is_empty() {
            fs.push(Finding::error(
                "plan.bad-output",
                span,
                format!("calibrate output {:?} is scalar — accumulators need a leading dimension", o.name),
            ));
        }
    }
    for s in declared.difference(&produced) {
        fs.push(Finding::warning(
            "plan.stat-uncovered",
            format!("artifacts.{}", a.name),
            format!("config stat {s:?} has no calibrate output — Eq. 2 scoring cannot cover its params"),
        ));
    }
}

/// Grad-score outputs are `gradmag:P` planes with exactly P's element count.
fn check_grad_outputs(fs: &mut Vec<Finding>, cfg: &ModelConfig, a: &ArtifactSpec) {
    for (i, o) in a.outputs.iter().enumerate() {
        let span = format!("artifacts.{}.outputs[{i}]", a.name);
        let p = match o.name.strip_prefix("gradmag:") {
            Some(p) => p,
            None => {
                fs.push(Finding::error(
                    "plan.bad-output",
                    span,
                    format!("grad_scores output {:?} is not a gradmag:* plane", o.name),
                ));
                continue;
            }
        };
        match cfg.params.iter().find(|ps| ps.name == p) {
            None => fs.push(Finding::error(
                "plan.unknown-param",
                span,
                format!("gradmag plane targets param {p:?}, absent from config {:?}", cfg.name),
            )),
            Some(spec) if spec.numel() != o.numel() => {
                fs.push(Finding::error(
                    "plan.shape-mismatch",
                    span,
                    format!("gradmag plane for {p:?} has {} elements, param has {}", o.numel(), spec.numel()),
                ));
            }
            Some(_) => {}
        }
    }
}

/// The serving contract, mirroring `serve::BatchPlan::new` + the response
/// path: inputs are only `param:*` + one exact-shaped `images`; the graph
/// answers through a `logits` output of `[batch, num_classes]`.
fn check_fwd(fs: &mut Vec<Finding>, m: &Manifest, cfg: &ModelConfig, a: &ArtifactSpec) {
    let mut has_images = false;
    for (i, io) in a.inputs.iter().enumerate() {
        let span = format!("artifacts.{}.inputs[{i}]", a.name);
        if let Some(p) = io.name.strip_prefix("param:") {
            check_param_slot(fs, cfg, io, p, &span, false);
        } else if io.name == "images" {
            has_images = true;
            let want = vec![m.batch, cfg.image_size, cfg.image_size, cfg.channels];
            expect_shape(fs, io, &want, &span);
            expect_dtype(fs, io, Dtype::F32, &span);
        } else {
            fs.push(Finding::error(
                "plan.unroutable-input",
                span,
                format!("fwd input {:?} is neither param:* nor images — BatchPlan::new rejects it", io.name),
            ));
        }
    }
    if !has_images {
        fs.push(Finding::error(
            "plan.missing-input",
            format!("artifacts.{}", a.name),
            "fwd artifact has no images input".to_string(),
        ));
    }
    match a.outputs.iter().enumerate().find(|(_, o)| o.name == "logits") {
        None => fs.push(Finding::error(
            "plan.missing-output",
            format!("artifacts.{}", a.name),
            "fwd artifact has no logits output".to_string(),
        )),
        Some((i, o)) => {
            let span = format!("artifacts.{}.outputs[{i}]", a.name);
            expect_shape(fs, o, &[m.batch, cfg.num_classes], &span);
            expect_dtype(fs, o, Dtype::F32, &span);
        }
    }
}

fn expect_shape(fs: &mut Vec<Finding>, io: &IoSpec, want: &[usize], span: &str) {
    if io.shape != want {
        fs.push(Finding::error(
            "plan.shape-mismatch",
            span.to_string(),
            format!("{:?} has shape {:?}, contract requires {want:?}", io.name, io.shape),
        ));
    }
}

fn expect_dtype(fs: &mut Vec<Finding>, io: &IoSpec, want: Dtype, span: &str) {
    if io.dtype != want {
        fs.push(Finding::error(
            "plan.dtype-mismatch",
            span.to_string(),
            format!("{:?} has dtype {:?}, contract requires {want:?}", io.name, io.dtype),
        ));
    }
}
