//! Static contract analysis for the manifest→plan→delta pipeline.
//!
//! `taskedge check` (and the [`check_dir`] entry point behind it) validates
//! an artifact directory *without* a device, PJRT, or any HLO loading —
//! every contract the runtime would enforce lazily at load/compile/step
//! time is proven up front from the manifest text alone:
//!
//! - **manifest integrity** ([`manifest_check`]): well-formed JSON with
//!   unique keys, schema-valid configs and artifacts, `num_params`
//!   consistent with the parameter table, referential integrity for
//!   `lora_targets`/adapters/artifact→config edges, artifact files present
//!   on disk, one authoritative batch size.
//! - **plan routing** ([`plan_check`]): dry-compiles the slot routing of
//!   every artifact through the *real* `classify_input`/`classify_output`
//!   used by `StepPlan` — every input routable, every write-back sink fed,
//!   shapes/dtypes agreeing with the `ParamSpec` table, and frozen inputs
//!   provably disjoint from mutated outputs.
//! - **delta admission** ([`delta_check`]): a `TEDL` delta file checked
//!   against the manifest (names, shapes, index bounds/order, strategy
//!   family) before any `apply_to`.
//! - **generation-key audit** ([`genkeys`]): the table of every prepared-
//!   literal cache-key site and its invalidation path, pinned to the real
//!   call sites by test.
//!
//! Output is a flat list of [`Finding`]s; the CLI renders them with
//! [`render_human`]/[`render_json`] and exits 1 iff [`has_errors`].

use std::path::{Path, PathBuf};

mod delta_check;
mod finding;
pub mod genkeys;
mod manifest_check;
mod plan_check;

pub use delta_check::{check_delta_file, check_delta_value};
pub use finding::{has_errors, render_human, render_json, Finding, Severity};

/// Analyze a manifest document in isolation (no filesystem checks unless
/// `dir` is given, in which case artifact files are required to exist
/// under it). Returns all findings, manifest-level and plan-level.
pub fn check_manifest_text(text: &str, dir: Option<&Path>) -> Vec<Finding> {
    let (mut fs, manifest) = manifest_check::check_manifest(text, dir);
    if let Some(m) = &manifest {
        fs.extend(plan_check::check_plans(m));
    }
    fs
}

/// Analyze an artifact directory: `dir/manifest.json` plus, for each
/// `(task, path)` pair, the delta file checked against the manifest.
pub fn check_dir(dir: &Path, deltas: &[(String, PathBuf)]) -> Vec<Finding> {
    let manifest_path = dir.join("manifest.json");
    let text = match std::fs::read_to_string(&manifest_path) {
        Ok(t) => t,
        Err(e) => {
            return vec![Finding::error(
                "manifest.unreadable",
                manifest_path.display().to_string(),
                format!("cannot read manifest: {e}"),
            )];
        }
    };
    let (mut fs, manifest) = manifest_check::check_manifest(&text, Some(dir));
    match &manifest {
        Some(m) => {
            fs.extend(plan_check::check_plans(m));
            for (task, path) in deltas {
                fs.extend(delta_check::check_delta_file(m, task, path));
            }
        }
        None => {
            if !deltas.is_empty() {
                fs.push(Finding::warning(
                    "delta.skipped",
                    "deltas",
                    format!(
                        "{} delta file(s) not checked: manifest has errors",
                        deltas.len()
                    ),
                ));
            }
        }
    }
    fs
}

#[cfg(test)]
mod tests {
    use super::*;

    // a minimal self-consistent manifest: num_params == summed numels,
    // canonical artifact name, routable fwd io
    const GOOD: &str = r#"{
        "version": 1,
        "batch": 2,
        "configs": {
            "t": {
                "image_size": 8, "patch_size": 4, "dim": 4, "depth": 1,
                "heads": 1, "mlp_ratio": 2, "num_classes": 10, "channels": 3,
                "prompt_len": 2, "adapter_dim": 2, "lora_rank": 2,
                "num_params": 40,
                "params": [
                    {"name": "head/kernel", "shape": [4, 10], "init": "zeros",
                     "masked": true, "stat": null}
                ],
                "lora_targets": [],
                "adapters": []
            }
        },
        "artifacts": [
            {"name": "fwd_t_b2", "kind": "fwd", "config": "t", "batch": 2,
             "file": "fwd_t_b2.hlo.txt",
             "inputs": [
                 {"name": "param:head/kernel", "shape": [4, 10], "dtype": "f32"},
                 {"name": "images", "shape": [2, 8, 8, 3], "dtype": "f32"}
             ],
             "outputs": [
                 {"name": "logits", "shape": [2, 10], "dtype": "f32"}
             ]}
        ]
    }"#;

    #[test]
    fn good_manifest_is_clean() {
        let fs = check_manifest_text(GOOD, None);
        assert!(
            !has_errors(&fs),
            "expected clean, got:\n{}",
            render_human(&fs)
        );
    }

    #[test]
    fn parse_failure_yields_single_parse_finding() {
        let fs = check_manifest_text("{\"version\": 1,,}", None);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].code, "parse.json");
        assert!(has_errors(&fs));
    }

    #[test]
    fn missing_dir_yields_unreadable() {
        let fs = check_dir(Path::new("/nonexistent/art"), &[]);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].code, "manifest.unreadable");
    }

    #[test]
    fn deltas_skipped_when_manifest_broken() {
        let dir = std::env::temp_dir().join("taskedge_check_broken_m");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{").unwrap();
        let deltas = vec![("t1".to_string(), dir.join("t1.tedl"))];
        let fs = check_dir(&dir, &deltas);
        assert!(fs.iter().any(|f| f.code == "delta.skipped"), "{fs:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
