//! Cross-artifact delta admission: verify a `TEDL` delta file against the
//! manifest — tensor names, shapes, index bounds, ordering, and strategy/
//! family compatibility — *before* any `apply_to` touches a store. This is
//! the same contract [`TaskDelta::validate_against`] enforces at apply
//! time, proven here from `ParamSpec`s alone so admission control (the
//! future fleet daemon) needs no backbone in memory.

use std::path::Path;

use crate::peft::Strategy;
use crate::runtime::{Manifest, ModelConfig};
use crate::vit::TaskDelta;

use super::finding::Finding;

/// Check the delta file at `path`, expected to adapt `task`, against `m`.
/// This is the untrusted-input entry: a file that does not even load (bad
/// magic, truncation, bounded-allocation violations) is a finding, not a
/// crash.
pub fn check_delta_file(m: &Manifest, task: &str, path: &Path) -> Vec<Finding> {
    let span = format!("delta.{task}");
    let delta = match TaskDelta::load(path) {
        Ok(d) => d,
        Err(e) => {
            return vec![Finding::error(
                "delta.load",
                span,
                format!("cannot load {}: {e:#}", path.display()),
            )];
        }
    };
    check_delta_value(m, task, &delta)
}

/// Check an already-loaded delta, expected to adapt `task`, against `m` —
/// the admission plane for deltas that arrive in memory (the fleet round
/// engine collects them this way before any `apply_to`).
pub fn check_delta_value(
    m: &Manifest,
    task: &str,
    delta: &TaskDelta,
) -> Vec<Finding> {
    let mut fs = Vec::new();
    let span = format!("delta.{task}");
    if delta.task != task {
        fs.push(Finding::error(
            "delta.task-mismatch",
            span.clone(),
            format!("file is labeled for task {:?}, was supplied as {task:?}", delta.task),
        ));
    }
    let cfg = match m.configs.get(&delta.config_name) {
        Some(c) => c,
        None => {
            fs.push(Finding::error(
                "delta.unknown-config",
                span,
                format!("delta targets config {:?}, which the manifest does not define", delta.config_name),
            ));
            return fs;
        }
    };
    check_against_config(&mut fs, cfg, delta, &span);
    check_family(&mut fs, delta, &span);
    fs
}

/// Mirror of `TaskDelta::validate_against`, driven by the manifest's
/// `ParamSpec` shapes instead of a live `ParamStore`.
fn check_against_config(
    fs: &mut Vec<Finding>,
    cfg: &ModelConfig,
    delta: &TaskDelta,
    span: &str,
) {
    for (name, sd) in &delta.sparse {
        let spec = match cfg.param(name) {
            Ok(s) => s,
            Err(_) => {
                fs.push(Finding::error(
                    "delta.unknown-target",
                    format!("{span}.sparse.{name}"),
                    format!("sparse plane targets param {name:?}, absent from config {:?}", cfg.name),
                ));
                continue;
            }
        };
        if sd.shape != spec.shape {
            fs.push(Finding::error(
                "delta.stale-shape",
                format!("{span}.sparse.{name}"),
                format!("plane recorded shape {:?}, config has {:?}", sd.shape, spec.shape),
            ));
            continue;
        }
        if sd.indices.len() != sd.values.len() {
            fs.push(Finding::error(
                "delta.malformed",
                format!("{span}.sparse.{name}"),
                format!("{} indices vs {} values", sd.indices.len(), sd.values.len()),
            ));
        }
        let numel = spec.numel();
        let mut prev: Option<u32> = None;
        for &i in &sd.indices {
            if i as usize >= numel {
                fs.push(Finding::error(
                    "delta.index-bounds",
                    format!("{span}.sparse.{name}"),
                    format!("index {i} out of bounds for {numel} elements (stale mask shape?)"),
                ));
                break;
            }
            if let Some(p) = prev {
                if i <= p {
                    fs.push(Finding::error(
                        "delta.index-order",
                        format!("{span}.sparse.{name}"),
                        format!("indices not strictly increasing ({p} then {i})"),
                    ));
                    break;
                }
            }
            prev = Some(i);
        }
    }

    for (name, t) in &delta.dense {
        match cfg.param(name) {
            Err(_) => fs.push(Finding::error(
                "delta.unknown-target",
                format!("{span}.dense.{name}"),
                format!("dense plane targets param {name:?}, absent from config {:?}", cfg.name),
            )),
            Ok(spec) if t.shape != spec.shape => {
                fs.push(Finding::error(
                    "delta.stale-shape",
                    format!("{span}.dense.{name}"),
                    format!("plane has shape {:?}, config has {:?}", t.shape, spec.shape),
                ));
            }
            Ok(_) => {}
        }
    }

    for (name, lf) in &delta.lora {
        let spec = match cfg.param(name) {
            Ok(s) => s,
            Err(_) => {
                fs.push(Finding::error(
                    "delta.unknown-target",
                    format!("{span}.lora.{name}"),
                    format!("lora factors target param {name:?}, absent from config {:?}", cfg.name),
                ));
                continue;
            }
        };
        if spec.shape.len() != 2 {
            fs.push(Finding::error(
                "delta.stale-shape",
                format!("{span}.lora.{name}"),
                format!("lora target {name:?} is rank-{}, not a 2-D weight", spec.shape.len()),
            ));
            continue;
        }
        let (d_in, d_out) = (spec.shape[0], spec.shape[1]);
        let ok_rank = lf.b.shape.len() == 2 && lf.a.shape.len() == 2;
        let r = if ok_rank { lf.b.shape[1] } else { 0 };
        if !ok_rank || lf.b.shape != [d_in, r] || lf.a.shape != [r, d_out] {
            fs.push(Finding::error(
                "delta.stale-shape",
                format!("{span}.lora.{name}"),
                format!(
                    "factors B {:?} / A {:?} do not factor a {:?} weight",
                    lf.b.shape, lf.a.shape, spec.shape
                ),
            ));
        }
        if lf.mask.shape != spec.shape {
            fs.push(Finding::error(
                "delta.stale-shape",
                format!("{span}.lora.{name}"),
                format!("lora mask shape {:?}, weight is {:?}", lf.mask.shape, spec.shape),
            ));
        }
        if !cfg.lora_targets.iter().any(|t| t == name) {
            fs.push(Finding::warning(
                "delta.lora-target-undeclared",
                format!("{span}.lora.{name}"),
                format!("{name:?} is not in config {:?}'s lora_targets", cfg.name),
            ));
        }
    }

    if !delta.extra.is_empty() {
        let names: Vec<&str> = delta.extra.keys().map(String::as_str).collect();
        fs.push(Finding::warning(
            "delta.unservable",
            format!("{span}.extra"),
            format!(
                "carries auxiliary tensors {names:?} with no backbone slot — \
                 the fwd graph cannot serve this delta (aux-family eval only)"
            ),
        ));
    }
}

/// Strategy/family coherence. The recorded strategy string is informational
/// (`Strategy::name()` output does not round-trip through `parse`), so an
/// unparseable string only downgrades this to a name-prefix heuristic.
fn check_family(fs: &mut Vec<Finding>, delta: &TaskDelta, span: &str) {
    let s = delta.strategy.as_str();
    let lora_family = match Strategy::parse(s) {
        Ok(st) => st.family() == crate::peft::Family::Lora,
        Err(_) => {
            if s.is_empty() {
                fs.push(Finding::info(
                    "delta.unknown-strategy",
                    span.to_string(),
                    "delta records no strategy; family checks skipped".to_string(),
                ));
                return;
            }
            s.contains("lora")
        }
    };
    if lora_family && delta.lora.is_empty() {
        fs.push(Finding::warning(
            "delta.family-mismatch",
            span.to_string(),
            format!("strategy {s:?} is LoRA-family but the delta carries no lora factors"),
        ));
    }
    if !lora_family && !delta.lora.is_empty() {
        fs.push(Finding::warning(
            "delta.family-mismatch",
            span.to_string(),
            format!("strategy {s:?} is not LoRA-family but the delta carries lora factors"),
        ));
    }
}
