//! Generation-key audit: the exhaustive table of every site that feeds a
//! cache key into [`Runtime::prepare`]'s generation-keyed prepared-literal
//! cache, with the mutation path that invalidates it. The invariant being
//! audited: **every prepared-literal cache key is refreshed by some
//! `ParamStore` mutation path** (`set`, `set_flat`, `reinit_head` — all of
//! which bump via `runtime::next_generation`) **or is a freshly minted
//! composed-set generation that can never be reused stale.**
//!
//! The table is asserted against the real call sites by the tests below
//! (`include_str!` over the sources): adding, removing, or re-keying a
//! prepare site without updating this table fails `cargo test`. That makes
//! stale-literal bugs — a store mutated without a generation bump, or a new
//! prepare site keyed on something no mutation path touches — a checked
//! property instead of a code-review hope.
//!
//! [`Runtime::donate_writeback`] sites are part of the same audit: a
//! donation *re-keys* an existing prepared set in place (slot refresh
//! first, then a release-store of the new generation), so each donor site
//! must key on a generation some mutation path mints fresh — the same
//! invariant as a prepare site, reached through the write-back door.

/// One prepared-literal cache-key site.
#[derive(Debug, Clone, Copy)]
pub struct GenKeySite {
    /// source file, relative to `rust/src/`
    pub file: &'static str,
    /// exact call-site text; `count` occurrences must exist in `file`
    pub pattern: &'static str,
    pub count: usize,
    /// where the cache key comes from
    pub key_source: &'static str,
    /// what invalidates it
    pub invalidated_by: &'static str,
}

/// Every `Runtime::prepare` / `Runtime::donate_writeback` key site outside
/// the runtime's own plumbing.
pub const GENERATION_KEY_SITES: &[GenKeySite] = &[
    GenKeySite {
        file: "coordinator/session.rs",
        pattern: "self.prep_gen(params.generation())",
        count: 4,
        key_source: "ParamStore::generation of the frozen backbone \
                     (calibrate, grad_scores, vpt/adapter train + eval)",
        invalidated_by: "ParamStore::set / set_flat / reinit_head bump the \
                         store to a fresh next_generation()",
    },
    GenKeySite {
        file: "coordinator/session.rs",
        pattern: "self.prep_gen(next_generation())",
        count: 1,
        key_source: "fresh composed-set generation for dense train's \
                     frozen mask set",
        invalidated_by: "minted per session; never reused, cannot be stale",
    },
    GenKeySite {
        file: "coordinator/session.rs",
        pattern: "self.prep_gen(session_gen)",
        count: 2,
        key_source: "one fresh composed-set generation shared by LoRA \
                     train + eval plans (same frozen backbone+mask set)",
        invalidated_by: "minted per session via next_generation(); the \
                         frozen set cannot change within the session",
    },
    GenKeySite {
        file: "coordinator/session.rs",
        pattern: "eval_template.plan.prepared(",
        count: 1,
        key_source: "ParamStore::generation of the in-training params at \
                     the first evaluated epoch (dense eval); later epochs \
                     refresh the same set by donation instead",
        invalidated_by: "every training write-back goes through \
                         ParamStore::set_flat, which bumps the generation",
    },
    GenKeySite {
        file: "coordinator/session.rs",
        pattern: "self.rt.donate_writeback(",
        count: 1,
        key_source: "ParamStore::generation of the post-epoch params, \
                     donated in place into the dense-eval prepared set",
        invalidated_by: "self-invalidating: the donation installs the new \
                         slot contents first, then release-stores the new \
                         generation — lookups at the old key miss",
    },
    GenKeySite {
        file: "coordinator/pretrain.rs",
        pattern: "Some(prep_gen)",
        count: 1,
        key_source: "fresh composed-set generation for pretrain's all-ones \
                     mask set (dense SGD through StepPlan::compile)",
        invalidated_by: "minted per run via next_generation(); never \
                         reused, cannot be stale",
    },
    GenKeySite {
        file: "serve/mod.rs",
        pattern: "rt.prepare(&plan.artifact, store.generation(), &fixed)",
        count: 1,
        key_source: "ParamStore::generation of the adapted serving store \
                     (DeviceBuilder::build and swap_delta both funnel here \
                      via prepare_store)",
        invalidated_by: "TaskDelta::apply_to clones + mutates via \
                         ParamStore::set, producing a fresh generation",
    },
    GenKeySite {
        file: "serve/mod.rs",
        pattern: ".donate_writeback(&old.prepared",
        count: 1,
        key_source: "ParamStore::generation of the freshly adapted store \
                     (sole-owner swap donates delta-touched slots in place)",
        invalidated_by: "self-invalidating re-key under the task's swap \
                         lock; shared sets fall back to prepare_store",
    },
];

#[cfg(test)]
mod tests {
    use super::*;

    const SESSION_SRC: &str = include_str!("../coordinator/session.rs");
    const PRETRAIN_SRC: &str = include_str!("../coordinator/pretrain.rs");
    const SERVE_SRC: &str = include_str!("../serve/mod.rs");
    const STORE_SRC: &str = include_str!("../vit/store.rs");

    fn src(file: &str) -> &'static str {
        match file {
            "coordinator/session.rs" => SESSION_SRC,
            "coordinator/pretrain.rs" => PRETRAIN_SRC,
            "serve/mod.rs" => SERVE_SRC,
            other => panic!("audit table names unknown file {other:?}"),
        }
    }

    fn count(hay: &str, needle: &str) -> usize {
        hay.match_indices(needle).count()
    }

    #[test]
    fn every_table_entry_matches_its_call_sites() {
        for site in GENERATION_KEY_SITES {
            assert_eq!(
                count(src(site.file), site.pattern),
                site.count,
                "audit table entry {:?} in {} no longer matches the source \
                 — update analysis/genkeys.rs alongside the key-site change",
                site.pattern,
                site.file,
            );
        }
    }

    #[test]
    fn table_is_exhaustive_over_prepare_entry_points() {
        // every session-side key choice funnels through prep_gen; the
        // audit entries must cover ALL of them
        let prep_gen_calls = count(SESSION_SRC, "self.prep_gen(");
        let covered: usize = GENERATION_KEY_SITES
            .iter()
            .filter(|s| s.pattern.starts_with("self.prep_gen("))
            .map(|s| s.count)
            .sum();
        assert_eq!(
            prep_gen_calls, covered,
            "a prep_gen call site exists that the genkeys audit table does \
             not cover"
        );

        // direct Runtime::prepare calls outside runtime/: exactly the
        // StepPlan::prepared funnel (session) and prepare_store (serve)
        assert_eq!(
            count(SESSION_SRC, "rt.prepare("),
            1,
            "session.rs grew a Runtime::prepare call outside the \
             StepPlan::prepared funnel — audit it in genkeys.rs"
        );
        assert_eq!(
            count(SERVE_SRC, "rt.prepare("),
            1,
            "serve/mod.rs grew a Runtime::prepare call outside \
             prepare_store — audit it in genkeys.rs"
        );
        // .prepared( re-prepare sites in session: the compile-time funnel
        // plus the dense-eval first-epoch prepare
        assert_eq!(
            count(SESSION_SRC, ".prepared("),
            2,
            "session.rs grew a StepPlan::prepared call site — audit it in \
             genkeys.rs"
        );

        // pretrain rides the session's StepPlan funnel exclusively: its
        // only key choice is the fresh prep_gen passed to compile
        assert_eq!(
            count(PRETRAIN_SRC, "rt.prepare("),
            0,
            "pretrain.rs grew a direct Runtime::prepare call — audit it in \
             genkeys.rs"
        );
        assert_eq!(
            count(PRETRAIN_SRC, "StepPlan::compile("),
            1,
            "pretrain.rs no longer compiles exactly one StepPlan — update \
             the genkeys audit"
        );

        // donation re-key sites: exactly the dense-eval write-back
        // (session) and the sole-owner swap (serve)
        assert_eq!(
            count(SESSION_SRC, ".donate_writeback("),
            1,
            "session.rs grew a Runtime::donate_writeback site — audit it \
             in genkeys.rs"
        );
        assert_eq!(
            count(SERVE_SRC, ".donate_writeback("),
            1,
            "serve/mod.rs grew a Runtime::donate_writeback site outside \
             donate_swap — audit it in genkeys.rs"
        );
    }

    #[test]
    fn every_param_store_mutation_path_bumps_the_generation() {
        // the invalidation half of the invariant: set and set_flat each
        // end in a generation bump (reinit_head mutates through set)
        let bumps = count(STORE_SRC, "self.generation = next_generation();");
        assert_eq!(
            bumps, 2,
            "ParamStore mutation paths changed — every mutation must bump \
             the generation, and the genkeys audit must reflect it"
        );
        assert!(
            STORE_SRC.contains("fn reinit_head"),
            "reinit_head disappeared; update the genkeys audit"
        );
    }
}
