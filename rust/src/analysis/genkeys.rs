//! Generation-key audit: the exhaustive table of every site that feeds a
//! cache key into [`Runtime::prepare`]'s generation-keyed prepared-literal
//! cache, with the mutation path that invalidates it. The invariant being
//! audited: **every prepared-literal cache key is refreshed by some
//! `ParamStore` mutation path** (`set`, `set_flat`, `reinit_head` — all of
//! which bump via `runtime::next_generation`) **or is a freshly minted
//! composed-set generation that can never be reused stale.**
//!
//! The table is asserted against the real call sites by the tests below
//! (`include_str!` over the sources): adding, removing, or re-keying a
//! prepare site without updating this table fails `cargo test`. That makes
//! stale-literal bugs — a store mutated without a generation bump, or a new
//! prepare site keyed on something no mutation path touches — a checked
//! property instead of a code-review hope.

/// One prepared-literal cache-key site.
#[derive(Debug, Clone, Copy)]
pub struct GenKeySite {
    /// source file, relative to `rust/src/`
    pub file: &'static str,
    /// exact call-site text; `count` occurrences must exist in `file`
    pub pattern: &'static str,
    pub count: usize,
    /// where the cache key comes from
    pub key_source: &'static str,
    /// what invalidates it
    pub invalidated_by: &'static str,
}

/// Every `Runtime::prepare` key site outside the runtime's own plumbing.
pub const GENERATION_KEY_SITES: &[GenKeySite] = &[
    GenKeySite {
        file: "coordinator/session.rs",
        pattern: "self.prep_gen(params.generation())",
        count: 4,
        key_source: "ParamStore::generation of the frozen backbone \
                     (calibrate, grad_scores, vpt/adapter train + eval)",
        invalidated_by: "ParamStore::set / set_flat / reinit_head bump the \
                         store to a fresh next_generation()",
    },
    GenKeySite {
        file: "coordinator/session.rs",
        pattern: "self.prep_gen(next_generation())",
        count: 1,
        key_source: "fresh composed-set generation for dense train's \
                     frozen mask set",
        invalidated_by: "minted per session; never reused, cannot be stale",
    },
    GenKeySite {
        file: "coordinator/session.rs",
        pattern: "self.prep_gen(session_gen)",
        count: 2,
        key_source: "one fresh composed-set generation shared by LoRA \
                     train + eval plans (same frozen backbone+mask set)",
        invalidated_by: "minted per session via next_generation(); the \
                         frozen set cannot change within the session",
    },
    GenKeySite {
        file: "coordinator/session.rs",
        pattern: "eval_template.plan.prepared(",
        count: 1,
        key_source: "ParamStore::generation of the in-training params, \
                     re-read per evaluated epoch (dense eval)",
        invalidated_by: "every training write-back goes through \
                         ParamStore::set_flat, which bumps the generation",
    },
    GenKeySite {
        file: "serve/mod.rs",
        pattern: "rt.prepare(&plan.artifact, store.generation(), &fixed)",
        count: 1,
        key_source: "ParamStore::generation of the adapted serving store \
                     (DeviceBuilder::build and swap_delta both funnel here \
                      via prepare_store)",
        invalidated_by: "TaskDelta::apply_to clones + mutates via \
                         ParamStore::set, producing a fresh generation",
    },
];

#[cfg(test)]
mod tests {
    use super::*;

    const SESSION_SRC: &str = include_str!("../coordinator/session.rs");
    const SERVE_SRC: &str = include_str!("../serve/mod.rs");
    const STORE_SRC: &str = include_str!("../vit/store.rs");

    fn src(file: &str) -> &'static str {
        match file {
            "coordinator/session.rs" => SESSION_SRC,
            "serve/mod.rs" => SERVE_SRC,
            other => panic!("audit table names unknown file {other:?}"),
        }
    }

    fn count(hay: &str, needle: &str) -> usize {
        hay.match_indices(needle).count()
    }

    #[test]
    fn every_table_entry_matches_its_call_sites() {
        for site in GENERATION_KEY_SITES {
            assert_eq!(
                count(src(site.file), site.pattern),
                site.count,
                "audit table entry {:?} in {} no longer matches the source \
                 — update analysis/genkeys.rs alongside the key-site change",
                site.pattern,
                site.file,
            );
        }
    }

    #[test]
    fn table_is_exhaustive_over_prepare_entry_points() {
        // every session-side key choice funnels through prep_gen; the
        // audit entries must cover ALL of them
        let prep_gen_calls = count(SESSION_SRC, "self.prep_gen(");
        let covered: usize = GENERATION_KEY_SITES
            .iter()
            .filter(|s| s.pattern.starts_with("self.prep_gen("))
            .map(|s| s.count)
            .sum();
        assert_eq!(
            prep_gen_calls, covered,
            "a prep_gen call site exists that the genkeys audit table does \
             not cover"
        );

        // direct Runtime::prepare calls outside runtime/: exactly the
        // StepPlan::prepared funnel (session) and prepare_store (serve)
        assert_eq!(
            count(SESSION_SRC, "rt.prepare("),
            1,
            "session.rs grew a Runtime::prepare call outside the \
             StepPlan::prepared funnel — audit it in genkeys.rs"
        );
        assert_eq!(
            count(SERVE_SRC, "rt.prepare("),
            1,
            "serve/mod.rs grew a Runtime::prepare call outside \
             prepare_store — audit it in genkeys.rs"
        );
        // .prepared( re-prepare sites in session: the compile-time funnel
        // plus the dense-eval per-epoch re-prepare
        assert_eq!(
            count(SESSION_SRC, ".prepared("),
            2,
            "session.rs grew a StepPlan::prepared call site — audit it in \
             genkeys.rs"
        );
    }

    #[test]
    fn every_param_store_mutation_path_bumps_the_generation() {
        // the invalidation half of the invariant: set and set_flat each
        // end in a generation bump (reinit_head mutates through set)
        let bumps = count(STORE_SRC, "self.generation = next_generation();");
        assert_eq!(
            bumps, 2,
            "ParamStore mutation paths changed — every mutation must bump \
             the generation, and the genkeys audit must reflect it"
        );
        assert!(
            STORE_SRC.contains("fn reinit_head"),
            "reinit_head disappeared; update the genkeys audit"
        );
    }
}
