//! Structured analyzer findings plus the human and JSON renderers.
//!
//! A [`Finding`] is one violated contract: a stable machine-readable `code`
//! (CI and the future fleet admin plane match on it), a `span` locating the
//! offending manifest/delta element, and a human `message`. Severities gate
//! the exit code: `taskedge check` fails only on [`Severity::Error`].

use std::fmt;

use crate::util::json::Json;

/// How bad a finding is. Ordering is by increasing severity so findings
/// can be sorted worst-first with `sort_by_key(Reverse(severity))`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Advisory only — never affects the exit code.
    Info,
    /// Suspicious but not provably broken (e.g. a delta that cannot be
    /// served via the fwd graph but is still valid for aux-family eval).
    Warning,
    /// A contract violation that would fail at load/compile/step time.
    Error,
}

impl Severity {
    pub fn as_str(&self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One violated (or suspicious) pipeline contract.
#[derive(Debug, Clone)]
pub struct Finding {
    pub severity: Severity,
    /// Stable dotted slug, e.g. `plan.unroutable-input`. Codes are part of
    /// the tool's interface: tests and CI match on them exactly.
    pub code: &'static str,
    /// Where: `configs.vit_s.params[3]`, `artifacts.fwd_t_b8.inputs[0]`,
    /// a file path, or `manifest` for document-level findings.
    pub span: String,
    pub message: String,
}

impl Finding {
    pub fn error(code: &'static str, span: impl Into<String>, message: impl Into<String>) -> Finding {
        Finding { severity: Severity::Error, code, span: span.into(), message: message.into() }
    }

    pub fn warning(code: &'static str, span: impl Into<String>, message: impl Into<String>) -> Finding {
        Finding { severity: Severity::Warning, code, span: span.into(), message: message.into() }
    }

    pub fn info(code: &'static str, span: impl Into<String>, message: impl Into<String>) -> Finding {
        Finding { severity: Severity::Info, code, span: span.into(), message: message.into() }
    }
}

/// True when any finding is an [`Severity::Error`] — the exit-1 predicate.
pub fn has_errors(findings: &[Finding]) -> bool {
    findings.iter().any(|f| f.severity == Severity::Error)
}

/// One line per finding (worst first), plus a summary tail line. Empty
/// input renders the all-clear line alone.
pub fn render_human(findings: &[Finding]) -> String {
    let mut sorted: Vec<&Finding> = findings.iter().collect();
    sorted.sort_by_key(|f| std::cmp::Reverse(f.severity));
    let mut out = String::new();
    for f in &sorted {
        out.push_str(&format!(
            "{}[{}] {}: {}\n",
            f.severity, f.code, f.span, f.message
        ));
    }
    let errors = findings.iter().filter(|f| f.severity == Severity::Error).count();
    let warnings = findings
        .iter()
        .filter(|f| f.severity == Severity::Warning)
        .count();
    if findings.is_empty() {
        out.push_str("check: clean (no findings)\n");
    } else {
        out.push_str(&format!(
            "check: {errors} error(s), {warnings} warning(s), {} finding(s) total\n",
            findings.len()
        ));
    }
    out
}

/// Machine form: `{"findings":[{severity,code,span,message},...],
/// "errors":N,"warnings":N}` — the schema documented in docs/check.md.
pub fn render_json(findings: &[Finding]) -> String {
    let items: Vec<Json> = findings
        .iter()
        .map(|f| {
            Json::obj(vec![
                ("severity", f.severity.as_str().into()),
                ("code", f.code.into()),
                ("span", f.span.as_str().into()),
                ("message", f.message.as_str().into()),
            ])
        })
        .collect();
    let errors = findings.iter().filter(|f| f.severity == Severity::Error).count();
    let warnings = findings
        .iter()
        .filter(|f| f.severity == Severity::Warning)
        .count();
    Json::obj(vec![
        ("findings", Json::Arr(items)),
        ("errors", errors.into()),
        ("warnings", warnings.into()),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn severity_orders_and_renders() {
        assert!(Severity::Error > Severity::Warning);
        assert!(Severity::Warning > Severity::Info);
        assert_eq!(Severity::Error.to_string(), "error");
    }

    #[test]
    fn human_renderer_sorts_errors_first() {
        let fs = vec![
            Finding::info("a.b", "s1", "m1"),
            Finding::error("c.d", "s2", "m2"),
        ];
        let text = render_human(&fs);
        let err_pos = text.find("error[c.d]").unwrap();
        let info_pos = text.find("info[a.b]").unwrap();
        assert!(err_pos < info_pos, "{text}");
        assert!(text.contains("1 error(s), 0 warning(s), 2 finding(s)"));
        assert!(has_errors(&fs));
    }

    #[test]
    fn json_renderer_round_trips() {
        let fs = vec![Finding::warning("x.y", "sp", "msg \"quoted\"")];
        let j = Json::parse(&render_json(&fs)).unwrap();
        assert_eq!(j.get("errors").unwrap().as_usize(), Some(0));
        assert_eq!(j.get("warnings").unwrap().as_usize(), Some(1));
        let arr = j.get("findings").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("code").unwrap().as_str(), Some("x.y"));
        assert_eq!(arr[0].get("message").unwrap().as_str(), Some("msg \"quoted\""));
        assert!(!has_errors(&fs));
    }

    #[test]
    fn clean_run_renders_all_clear() {
        assert!(render_human(&[]).contains("clean"));
        let j = Json::parse(&render_json(&[])).unwrap();
        assert_eq!(j.get("findings").unwrap().as_arr().unwrap().len(), 0);
    }
}
