//! Manifest integrity: a tolerant walk over the raw JSON tree that reports
//! *every* violated field contract (where the strict [`Manifest::parse`]
//! stops at the first), plus the semantic checks the strict parser does not
//! do — `num_params` accounting, `lora_targets`/`adapters` referential
//! integrity, canonical artifact naming, and on-disk artifact presence.
//!
//! The walk never panics on malformed input and keeps going past errors so
//! one `taskedge check` run surfaces the full damage report. Only when the
//! walk finds no errors is the strict parser invoked (it must then succeed;
//! a disagreement is itself reported as `parse.strict`).

use std::collections::BTreeSet;
use std::path::Path;

use crate::runtime::Manifest;
use crate::util::json::Json;

use super::finding::{has_errors, Finding};

/// The two dtypes the runtime substrate supports (`Dtype::parse`).
const DTYPES: [&str; 2] = ["f32", "i32"];

/// Numeric fields every model config must carry (mirrors the strict parse).
const CONFIG_NUMS: [&str; 12] = [
    "image_size",
    "patch_size",
    "dim",
    "depth",
    "heads",
    "mlp_ratio",
    "num_classes",
    "channels",
    "prompt_len",
    "adapter_dim",
    "lora_rank",
    "num_params",
];

/// Walk `text` and report all manifest-level findings. Returns the strictly
/// parsed [`Manifest`] only when the walk was error-free, so downstream
/// plan/delta checks always operate on a structurally sound document.
pub(crate) fn check_manifest(
    text: &str,
    dir: Option<&Path>,
) -> (Vec<Finding>, Option<Manifest>) {
    let mut fs = Vec::new();
    let j = match Json::parse(text) {
        Ok(j) => j,
        Err(e) => {
            let code = if e.msg.contains("duplicate key") {
                "parse.duplicate-key"
            } else {
                "parse.json"
            };
            fs.push(Finding::error(code, format!("byte {}", e.pos), e.to_string()));
            return (fs, None);
        }
    };
    if j.as_obj().is_none() {
        fs.push(Finding::error("parse.json", "manifest", "top-level value is not an object"));
        return (fs, None);
    }

    if let Some(v) = get_usize(&mut fs, &j, "version", "manifest") {
        if v != 1 {
            fs.push(Finding::error(
                "manifest.version",
                "manifest",
                format!("unsupported manifest version {v} (this runtime reads version 1)"),
            ));
        }
    }
    let batch = get_usize(&mut fs, &j, "batch", "manifest");
    if batch == Some(0) {
        fs.push(Finding::error("manifest.bad-type", "manifest", "batch must be >= 1"));
    }

    let mut config_names: BTreeSet<String> = BTreeSet::new();
    match j.get("configs") {
        None => fs.push(missing("configs", "manifest")),
        Some(cj) => match cj.as_obj() {
            None => fs.push(Finding::error("manifest.bad-type", "configs", "configs must be an object")),
            Some(m) => {
                for (name, c) in m {
                    config_names.insert(name.clone());
                    check_config(&mut fs, name, c);
                }
            }
        },
    }

    match j.get("artifacts") {
        None => fs.push(missing("artifacts", "manifest")),
        Some(aj) => match aj.as_arr() {
            None => fs.push(Finding::error("manifest.bad-type", "artifacts", "artifacts must be an array")),
            Some(arr) => {
                let mut seen: BTreeSet<String> = BTreeSet::new();
                for (i, a) in arr.iter().enumerate() {
                    check_artifact(&mut fs, i, a, batch, &config_names, &mut seen, dir);
                }
            }
        },
    }

    if has_errors(&fs) {
        return (fs, None);
    }
    match Manifest::parse(text) {
        Ok(m) => (fs, Some(m)),
        Err(e) => {
            // the walk missed something the strict parser rejects — still a
            // real finding, and a gap worth closing in the walker
            fs.push(Finding::error("parse.strict", "manifest", format!("{e:#}")));
            (fs, None)
        }
    }
}

fn check_config(fs: &mut Vec<Finding>, name: &str, c: &Json) {
    let span = format!("configs.{name}");
    if c.as_obj().is_none() {
        fs.push(Finding::error("manifest.bad-type", span, "config must be an object"));
        return;
    }
    for key in CONFIG_NUMS {
        get_usize(fs, c, key, &span);
    }

    let mut param_names: BTreeSet<&str> = BTreeSet::new();
    let mut param_numel_sum: usize = 0;
    let mut params_ok = true;
    match c.get("params") {
        None => {
            fs.push(missing("params", &span));
            params_ok = false;
        }
        Some(pj) => match pj.as_arr() {
            None => {
                fs.push(Finding::error("manifest.bad-type", format!("{span}.params"), "params must be an array"));
                params_ok = false;
            }
            Some(arr) => {
                for (i, p) in arr.iter().enumerate() {
                    let pspan = format!("{span}.params[{i}]");
                    let pname = get_str(fs, p, "name", &pspan);
                    let shape = match p.get("shape") {
                        None => {
                            fs.push(missing("shape", &pspan));
                            None
                        }
                        Some(sj) => get_shape(fs, sj, &pspan),
                    };
                    get_str(fs, p, "init", &pspan);
                    get_bool(fs, p, "masked", &pspan);
                    if let Some(st) = p.get("stat") {
                        if !matches!(st, Json::Null | Json::Str(_)) {
                            fs.push(Finding::error(
                                "manifest.bad-type",
                                format!("{pspan}.stat"),
                                "stat must be a string or null",
                            ));
                        }
                    }
                    match (pname, shape) {
                        (Some(n), Some(sh)) => {
                            if !param_names.insert(n) {
                                fs.push(Finding::error(
                                    "manifest.dup-param",
                                    pspan,
                                    format!("duplicate param name {n:?}"),
                                ));
                                params_ok = false;
                            }
                            param_numel_sum += sh.iter().product::<usize>();
                        }
                        _ => params_ok = false,
                    }
                }
            }
        },
    }

    // num_params must equal the summed ParamSpec numels (the AOT compiler
    // guarantees this; a mismatch means the params list was edited by hand
    // or truncated in transit) — only meaningful when every param walked
    // cleanly, else the sum itself is off
    if params_ok {
        if let Some(np) = c.get("num_params").and_then(Json::as_usize) {
            if np != param_numel_sum {
                fs.push(Finding::error(
                    "config.num-params-mismatch",
                    span.clone(),
                    format!("num_params is {np} but the params list sums to {param_numel_sum}"),
                ));
            }
        }
    }

    match c.get("lora_targets") {
        None => fs.push(missing("lora_targets", &span)),
        Some(lj) => match lj.as_arr() {
            None => fs.push(Finding::error(
                "manifest.bad-type",
                format!("{span}.lora_targets"),
                "lora_targets must be an array",
            )),
            Some(arr) => {
                for (i, t) in arr.iter().enumerate() {
                    let tspan = format!("{span}.lora_targets[{i}]");
                    match t.as_str() {
                        None => fs.push(Finding::error(
                            "manifest.bad-type",
                            tspan,
                            format!("lora_targets entries must be strings, got {t}"),
                        )),
                        // each target must name a real 2-D param: LoRA
                        // factors (B·A) only factor matrices
                        Some(t) if params_ok => {
                            if !param_names.contains(t) {
                                fs.push(Finding::error(
                                    "config.bad-lora-target",
                                    tspan,
                                    format!("lora target {t:?} names no param of config {name:?}"),
                                ));
                            } else if let Some(rank) = param_rank(c, t) {
                                if rank != 2 {
                                    fs.push(Finding::error(
                                        "config.bad-lora-target",
                                        tspan,
                                        format!("lora target {t:?} is rank-{rank}, not a 2-D weight"),
                                    ));
                                }
                            }
                        }
                        Some(_) => {}
                    }
                }
            }
        },
    }

    match c.get("adapters") {
        None => fs.push(missing("adapters", &span)),
        Some(aj) => match aj.as_arr() {
            None => fs.push(Finding::error(
                "manifest.bad-type",
                format!("{span}.adapters"),
                "adapters must be an array",
            )),
            Some(arr) => {
                let mut seen: BTreeSet<&str> = BTreeSet::new();
                for (i, a) in arr.iter().enumerate() {
                    let aspan = format!("{span}.adapters[{i}]");
                    let aname = get_str(fs, a, "name", &aspan);
                    match a.get("shape") {
                        None => fs.push(missing("shape", &aspan)),
                        Some(sj) => {
                            get_shape(fs, sj, &aspan);
                        }
                    }
                    if let Some(n) = aname {
                        if !seen.insert(n) {
                            fs.push(Finding::error(
                                "config.bad-adapter",
                                aspan.clone(),
                                format!("duplicate adapter name {n:?}"),
                            ));
                        }
                        // adapter tensors live in the aux state map, NOT the
                        // backbone: a name collision with a param would make
                        // the two indistinguishable in a delta's extra set
                        if param_names.contains(n) {
                            fs.push(Finding::error(
                                "config.bad-adapter",
                                aspan,
                                format!("adapter {n:?} collides with a backbone param name"),
                            ));
                        }
                    }
                }
            }
        },
    }
}

#[allow(clippy::too_many_arguments)]
fn check_artifact(
    fs: &mut Vec<Finding>,
    i: usize,
    a: &Json,
    manifest_batch: Option<usize>,
    config_names: &BTreeSet<String>,
    seen: &mut BTreeSet<String>,
    dir: Option<&Path>,
) {
    let idx_span = format!("artifacts[{i}]");
    if a.as_obj().is_none() {
        fs.push(Finding::error("manifest.bad-type", idx_span, "artifact must be an object"));
        return;
    }
    let name = get_str(fs, a, "name", &idx_span).map(str::to_string);
    let span = match &name {
        Some(n) => format!("artifacts.{n}"),
        None => idx_span,
    };
    if let Some(n) = &name {
        if !seen.insert(n.clone()) {
            fs.push(Finding::error(
                "manifest.dup-artifact",
                span.clone(),
                format!("duplicate artifact name {n:?}"),
            ));
        }
    }

    let kind = get_str(fs, a, "kind", &span).map(str::to_string);
    let config = get_str(fs, a, "config", &span).map(str::to_string);
    let batch = get_usize(fs, a, "batch", &span);
    let file = get_str(fs, a, "file", &span).map(str::to_string);

    if let Some(c) = &config {
        if !config_names.contains(c) {
            fs.push(Finding::error(
                "manifest.dangling-config",
                span.clone(),
                format!("artifact references config {c:?}, which the manifest does not define"),
            ));
        }
    }
    if let (Some(b), Some(mb)) = (batch, manifest_batch) {
        if b != mb {
            fs.push(Finding::error(
                "manifest.batch-skew",
                span.clone(),
                format!("artifact batch {b} disagrees with manifest batch {mb} (top-level batch is authoritative)"),
            ));
        }
    }
    // every lookup goes through `artifact_for`'s `{kind}_{config}_b{batch}`
    // naming — an artifact named anything else is unreachable dead weight
    if let (Some(n), Some(k), Some(c), Some(mb)) = (&name, &kind, &config, manifest_batch) {
        let canonical = format!("{k}_{c}_b{mb}");
        if *n != canonical {
            fs.push(Finding::warning(
                "manifest.noncanonical-name",
                span.clone(),
                format!("artifact {n:?} is not the canonical {canonical:?}; artifact_for() will never resolve it"),
            ));
        }
    }
    if let (Some(f), Some(d)) = (&file, dir) {
        if !d.join(f).is_file() {
            fs.push(Finding::error(
                "artifact.missing-file",
                span.clone(),
                format!("artifact file {f:?} not found in {}", d.display()),
            ));
        }
    }

    for key in ["inputs", "outputs"] {
        match a.get(key) {
            None => fs.push(missing(key, &span)),
            Some(io) => match io.as_arr() {
                None => fs.push(Finding::error(
                    "manifest.bad-type",
                    format!("{span}.{key}"),
                    format!("{key} must be an array of io specs"),
                )),
                Some(arr) => {
                    for (k, s) in arr.iter().enumerate() {
                        let ispan = format!("{span}.{key}[{k}]");
                        get_str(fs, s, "name", &ispan);
                        match s.get("shape") {
                            None => fs.push(missing("shape", &ispan)),
                            Some(sj) => {
                                get_shape(fs, sj, &ispan);
                            }
                        }
                        match get_str(fs, s, "dtype", &ispan) {
                            Some(d) if !DTYPES.contains(&d) => {
                                fs.push(Finding::error(
                                    "manifest.bad-dtype",
                                    ispan,
                                    format!("unsupported dtype {d:?} (runtime supports {DTYPES:?})"),
                                ));
                            }
                            _ => {}
                        }
                    }
                }
            },
        }
    }
}

// -- field helpers (tolerant: report + return None, never abort) ------------

fn missing(key: &str, span: &str) -> Finding {
    Finding::error(
        "manifest.missing-field",
        span.to_string(),
        format!("missing required field {key:?}"),
    )
}

fn get_str<'a>(fs: &mut Vec<Finding>, obj: &'a Json, key: &str, span: &str) -> Option<&'a str> {
    match obj.get(key) {
        None => {
            fs.push(missing(key, span));
            None
        }
        Some(v) => match v.as_str() {
            Some(s) => Some(s),
            None => {
                fs.push(Finding::error(
                    "manifest.bad-type",
                    format!("{span}.{key}"),
                    format!("{key} must be a string, got {v}"),
                ));
                None
            }
        },
    }
}

fn get_bool(fs: &mut Vec<Finding>, obj: &Json, key: &str, span: &str) -> Option<bool> {
    match obj.get(key) {
        None => {
            fs.push(missing(key, span));
            None
        }
        Some(v) => match v.as_bool() {
            Some(b) => Some(b),
            None => {
                fs.push(Finding::error(
                    "manifest.bad-type",
                    format!("{span}.{key}"),
                    format!("{key} must be a boolean, got {v}"),
                ));
                None
            }
        },
    }
}

/// Non-negative integer field. Catches what `Json::as_usize` silently
/// truncates: floats, negatives.
fn get_usize(fs: &mut Vec<Finding>, obj: &Json, key: &str, span: &str) -> Option<usize> {
    match obj.get(key) {
        None => {
            fs.push(missing(key, span));
            None
        }
        Some(v) => match v.as_f64() {
            Some(f) if f >= 0.0 && f.fract() == 0.0 => Some(f as usize),
            _ => {
                fs.push(Finding::error(
                    "manifest.bad-type",
                    format!("{span}.{key}"),
                    format!("{key} must be a non-negative integer, got {v}"),
                ));
                None
            }
        },
    }
}

/// A shape value: array of non-negative integers. Catches what the strict
/// parser's `as_usize_vec` + `filter_map` silently drops.
fn get_shape(fs: &mut Vec<Finding>, sj: &Json, span: &str) -> Option<Vec<usize>> {
    let arr = match sj.as_arr() {
        Some(a) => a,
        None => {
            fs.push(Finding::error(
                "manifest.bad-shape",
                format!("{span}.shape"),
                format!("shape must be an array, got {sj}"),
            ));
            return None;
        }
    };
    let mut out = Vec::with_capacity(arr.len());
    for (i, d) in arr.iter().enumerate() {
        match d.as_f64() {
            Some(f) if f >= 0.0 && f.fract() == 0.0 => out.push(f as usize),
            _ => {
                fs.push(Finding::error(
                    "manifest.bad-shape",
                    format!("{span}.shape[{i}]"),
                    format!("shape entries must be non-negative integers, got {d}"),
                ));
                return None;
            }
        }
    }
    Some(out)
}

fn param_rank(c: &Json, pname: &str) -> Option<usize> {
    c.get("params")?
        .as_arr()?
        .iter()
        .find(|p| p.get("name").and_then(Json::as_str) == Some(pname))?
        .get("shape")?
        .as_arr()
        .map(<[Json]>::len)
}
