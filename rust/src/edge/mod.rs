//! Edge-device cost model: memory admission, energy/latency estimates, and
//! the N:M sparse-tensor-core speedup model (the paper's hardware is gated
//! — DESIGN.md §2 — so acceleration is modeled analytically while the mask
//! *format* invariant is enforced for real).

pub mod profiles;

pub use profiles::{DeviceProfile, DEVICE_PROFILES};

use crate::peft::MemoryFootprint;

/// Analytic FLOPs of one ViT fine-tuning step (fwd + bwd ≈ 3x fwd).
pub fn step_flops(
    dim: usize,
    depth: usize,
    mlp_ratio: usize,
    tokens: usize,
    batch: usize,
) -> f64 {
    let d = dim as f64;
    let t = tokens as f64;
    // per block: qkv (3d^2) + attn (2 t d) + proj (d^2) + mlp (2 r d^2)
    let per_tok = 4.0 * d * d + (2 * mlp_ratio) as f64 * d * d;
    let attn = 2.0 * t * d;
    let fwd = (batch * depth) as f64 * (t * per_tok + t * attn) * 2.0;
    3.0 * fwd // fwd + 2x for backward
}

/// Modeled speedup of the masked-update + sparse-state path relative to a
/// dense update, as a function of trainable density. The paper's N:M path
/// additionally accelerates the matmul on sparse tensor cores.
#[derive(Debug, Clone, Copy)]
pub struct NmSpeedupModel {
    /// fraction of step time spent in weight update + optimizer
    pub update_frac: f64,
    /// fraction of step time in matmuls that N:M can accelerate
    pub matmul_frac: f64,
    /// achievable tensor-core speedup at 2:4 (NVIDIA claims ~2x; realized
    /// end-to-end is lower)
    pub tc_speedup: f64,
}

impl Default for NmSpeedupModel {
    fn default() -> Self {
        NmSpeedupModel { update_frac: 0.15, matmul_frac: 0.55, tc_speedup: 1.6 }
    }
}

impl NmSpeedupModel {
    /// End-to-end step speedup for (n, m) structured sparsity at a given
    /// trainable density (Amdahl over update + matmul fractions).
    pub fn step_speedup(&self, n: usize, m: usize, density: f64) -> f64 {
        let update_gain = 1.0 / density.max(1e-6); // sparse optimizer state
        let matmul_gain = if 2 * n <= m { self.tc_speedup } else { 1.0 };
        let rest = 1.0 - self.update_frac - self.matmul_frac;
        1.0 / (rest
            + self.update_frac / update_gain.min(8.0)
            + self.matmul_frac / matmul_gain)
    }
}

/// Energy model: J per step = FLOPs / (efficiency GFLOPs/J).
pub fn step_energy_joules(flops: f64, gflops_per_joule: f64) -> f64 {
    flops / (gflops_per_joule * 1e9)
}

/// Admission decision for running a fine-tuning job on a device.
#[derive(Debug, Clone)]
pub struct Admission {
    pub fits: bool,
    pub required_bytes: usize,
    pub available_bytes: usize,
    pub headroom: f64,
}

pub fn admit(profile: &DeviceProfile, footprint: &MemoryFootprint) -> Admission {
    let required = footprint.total_sparse() + profile.runtime_overhead_bytes;
    Admission {
        fits: required <= profile.memory_bytes,
        required_bytes: required,
        available_bytes: profile.memory_bytes,
        headroom: profile.memory_bytes as f64 / required.max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_positive_and_scale() {
        let f1 = step_flops(64, 2, 2, 17, 16);
        let f2 = step_flops(128, 4, 4, 65, 16);
        assert!(f1 > 0.0 && f2 > 10.0 * f1);
    }

    #[test]
    fn nm_speedup_monotone_in_sparsity() {
        let m = NmSpeedupModel::default();
        let dense = m.step_speedup(4, 4, 1.0);
        let sparse24 = m.step_speedup(2, 4, 0.01);
        let sparse14 = m.step_speedup(1, 4, 0.01);
        assert!(dense <= 1.01);
        assert!(sparse24 > 1.2, "{sparse24}");
        assert!(sparse14 >= sparse24 * 0.99);
    }

    #[test]
    fn admission_thresholds() {
        let prof = &DEVICE_PROFILES[0];
        let small = MemoryFootprint {
            weights_bytes: 1000,
            grad_dense_bytes: 1000,
            grad_sparse_bytes: 10,
            optimizer_bytes: 20,
            activation_bytes: 100,
        };
        let a = admit(prof, &small);
        assert!(a.fits);
        let huge = MemoryFootprint {
            weights_bytes: prof.memory_bytes,
            grad_dense_bytes: 0,
            grad_sparse_bytes: prof.memory_bytes,
            optimizer_bytes: 0,
            activation_bytes: 0,
        };
        assert!(!admit(prof, &huge).fits);
    }
}
