//! Edge device profiles used by the fleet simulator and cost model.
//! Numbers are public-spec figures for representative device classes.

#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: &'static str,
    pub memory_bytes: usize,
    /// sustained training throughput, GFLOPs/s (fp32-equivalent)
    pub gflops: f64,
    /// energy efficiency, GFLOPs/J
    pub gflops_per_joule: f64,
    /// resident runtime + framework overhead
    pub runtime_overhead_bytes: usize,
    /// supports N:M sparse acceleration (Ampere-class tensor cores)
    pub nm_acceleration: bool,
}

pub const DEVICE_PROFILES: &[DeviceProfile] = &[
    DeviceProfile {
        name: "jetson-orin-nano",
        memory_bytes: 8 * 1024 * 1024 * 1024,
        gflops: 1280.0,
        gflops_per_joule: 85.0,
        runtime_overhead_bytes: 512 * 1024 * 1024,
        nm_acceleration: true,
    },
    DeviceProfile {
        name: "jetson-nano",
        memory_bytes: 4 * 1024 * 1024 * 1024,
        gflops: 236.0,
        gflops_per_joule: 47.0,
        runtime_overhead_bytes: 512 * 1024 * 1024,
        nm_acceleration: false,
    },
    DeviceProfile {
        name: "phone-flagship",
        memory_bytes: 6 * 1024 * 1024 * 1024,
        gflops: 900.0,
        gflops_per_joule: 150.0,
        runtime_overhead_bytes: 768 * 1024 * 1024,
        nm_acceleration: false,
    },
    DeviceProfile {
        name: "raspberry-pi-4",
        memory_bytes: 2 * 1024 * 1024 * 1024,
        gflops: 13.5,
        gflops_per_joule: 4.5,
        runtime_overhead_bytes: 256 * 1024 * 1024,
        nm_acceleration: false,
    },
    DeviceProfile {
        name: "rtx4090-edge-server",
        memory_bytes: 24 * 1024 * 1024 * 1024,
        gflops: 40_000.0,
        gflops_per_joule: 180.0,
        runtime_overhead_bytes: 1024 * 1024 * 1024,
        nm_acceleration: true,
    },
];

pub fn profile_by_name(name: &str) -> Option<&'static DeviceProfile> {
    DEVICE_PROFILES.iter().find(|p| p.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup() {
        assert!(profile_by_name("jetson-nano").is_some());
        assert!(profile_by_name("nope").is_none());
    }

    #[test]
    fn profiles_sane() {
        for p in DEVICE_PROFILES {
            assert!(p.memory_bytes > p.runtime_overhead_bytes);
            assert!(p.gflops > 0.0 && p.gflops_per_joule > 0.0);
        }
    }
}
