//! TaskEdge CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   info                          show manifest / artifact inventory
//!   pretrain                      train the backbone on the synthetic corpus
//!   finetune                      run one (task, strategy) session
//!   evaluate                      evaluate a checkpoint on a task
//!   fleet                         schedule jobs across simulated devices
//!   fleet-serve                   coordinator daemon for networked rounds
//!   participate                   join a coordinator as a remote participant
//!   standby                       hot-standby coordinator (journal shipping
//!                                 + lease-based promotion)
//!   tasks                         list the SynthVTAB suite
//!
//! Run `taskedge <cmd> --help-args` for per-command options.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use taskedge::coordinator::{pretrain, FaultPlan, Fleet, FinetuneSession, Job,
                            PretrainConfig, RoundConfig, TrainConfig};
use taskedge::data::{generate_task, synthvtab, upstream_corpus, SYNTH_VTAB};
use taskedge::edge::{DEVICE_PROFILES};
use taskedge::info;
use taskedge::metrics::JsonlLogger;
use taskedge::peft::{DeltaSizeReport, Strategy};
use taskedge::runtime::Runtime;
use taskedge::util::bench::Table;
use taskedge::util::cli::Args;
use taskedge::util::rng::Rng;
use taskedge::vit::{ParamStore, TaskDelta};

const USAGE: &str = "\
taskedge — task-aware parameter-efficient fine-tuning at the edge

USAGE: taskedge <command> [options]

COMMANDS:
  info        manifest + artifact inventory
  tasks       list the SynthVTAB task suite
  pretrain    pretrain the backbone   [--config micro] [--steps 300]
              [--corpus-size 2048] [--lr 0.05] [--out ckpt.bin]
  finetune    fine-tune on one task   [--task caltech101]
              [--strategy taskedge:k=8] [--epochs 20] [--lr 1e-3]
              [--ckpt ckpt.bin] [--log runs.jsonl] [--delta-out task.delta]
  evaluate    evaluate a checkpoint   [--task ...] [--ckpt ckpt.bin]
  export-delta  diff two checkpoints into a sparse task delta
              --base ckpt.bin --tuned tuned.bin [--out task.delta]
  fleet       run jobs across devices [--strategies a,b,c] [--tasks t1,t2]
              [--devices jetson-nano,phone-flagship]
              round engine: [--delta-dir DIR] [--resume] [--quorum 1.0]
              [--fault-plan panic=0.3,stall=DEV:MS,die=DEV@PHASE]
              [--round-deadline-ms N] [--job-timeout-ms N]
              [--max-attempts 3] [--backoff-ms 50]
  fleet-serve run a networked round as the coordinator daemon
              [--bind 127.0.0.1:7700] [--participants N] [--sim]
              [--join-timeout-ms 60000] [--heartbeat-timeout-ms 3000]
              plus all `fleet` round options (--tasks, --strategies,
              --devices, --resume, --fault-plan ..., netdrop=RATE,
              netdup=RATE, netcorrupt=RATE, netdelay=MS,
              killprimary@PHASE, shipdrop=RATE) [--generation N]
  participate join a coordinator as a remote fleet participant
              --device jetson-nano [--addr 127.0.0.1:7700] [--sim]
              [--once] [--backoff-ms 200] [--max-reconnects 8]
              [--heartbeat-ms 0 (use coordinator's)]
              [--fault-plan disconnect=DEV@PHASE]
  standby     attach to a primary coordinator as a hot standby: persist
              the shipped round journal, promote when the primary's lease
              expires, and finish the round at the advertised address
              [--primary 127.0.0.1:7700] [--advertise 127.0.0.1:7701]
              --delta-dir DIR [--journal FILE] [--lease-ms 10000]
              plus all `fleet-serve` round options for the promoted run
  serve       drive the shared device executor [--tasks pets,dtd]
              [--requests 256] [--workers 2  (device-wide pool)]
              [--weights pets=4,dtd=1] [--linger-ms 2] [--max-queue 1024]
              [--deltas pets=pets.delta,dtd=dtd.delta]
              [--stats-interval SECS]
  run         run a declarative experiment  --config configs/fleet_demo.json
  check       static contract analysis of an artifact directory (no device)
              [--artifacts DIR] [--json] [--deltas task=file.delta,...]
              exit 0 = clean, 1 = error findings, 2 = tool failure

COMMON OPTIONS:
  --artifacts DIR   artifact directory (default: artifacts)
  --config NAME     model config (default: micro)
  --seed N          global seed (default: 42)
  --quiet / -v      log level
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env(&[
        "quiet",
        "v",
        "help",
        "no-pretrain",
        "json",
        "resume",
        "sim",
        "once",
    ]);
    if args.flag("help") || args.positional.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    if args.flag("quiet") {
        taskedge::util::set_log_level(0);
    } else if args.flag("v") {
        taskedge::util::set_log_level(2);
    }

    let cmd = args.positional[0].as_str();
    match cmd {
        "info" => cmd_info(&args),
        "tasks" => cmd_tasks(),
        "pretrain" => cmd_pretrain(&args),
        "finetune" => cmd_finetune(&args),
        "evaluate" => cmd_evaluate(&args),
        "export-delta" => cmd_export_delta(&args),
        "fleet" => cmd_fleet(&args),
        "fleet-serve" => cmd_fleet_serve(&args),
        "participate" => cmd_participate(&args),
        "standby" => cmd_standby(&args),
        "serve" => cmd_serve(&args),
        "run" => cmd_run(&args),
        "check" => cmd_check(&args),
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn load_runtime(args: &Args) -> Result<Runtime> {
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    Runtime::load(&dir)
}

fn cmd_info(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let m = rt.manifest();
    println!("manifest: batch={}, {} configs, {} artifacts",
             m.batch, m.configs.len(), m.artifacts.len());
    let mut t = Table::new("configs", &["name", "dim", "depth", "params",
                                        "masked params"]);
    for (name, c) in &m.configs {
        t.row(vec![
            name.clone(),
            c.dim.to_string(),
            c.depth.to_string(),
            c.num_params.to_string(),
            c.masked_param_count().to_string(),
        ]);
    }
    t.print();
    let mut t = Table::new("artifacts", &["name", "kind", "inputs", "outputs"]);
    for (name, a) in &m.artifacts {
        t.row(vec![
            name.clone(),
            a.kind.clone(),
            a.inputs.len().to_string(),
            a.outputs.len().to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_tasks() -> Result<()> {
    let mut t = Table::new("SynthVTAB-19", &["task", "group", "classes",
                                             "vtab analog"]);
    for spec in SYNTH_VTAB {
        t.row(vec![
            spec.name.to_string(),
            spec.group.label().to_string(),
            spec.classes.to_string(),
            spec.vtab_analog.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let config = args.str_or("config", "micro");
    let cfg = rt.manifest().config(&config)?;
    let seed = args.u64_or("seed", 42);
    let corpus_size = args.usize_or("corpus-size", 2048);
    let corpus = upstream_corpus(cfg.image_size, cfg.num_classes, corpus_size,
                                 seed)?;
    let mut params = ParamStore::init(cfg, &mut Rng::new(seed));
    let pcfg = PretrainConfig {
        steps: args.usize_or("steps", 300),
        lr: args.f32_or("lr", 0.05),
        weight_decay: args.f32_or("wd", 1e-4),
        seed,
        ..Default::default()
    };
    info!("pretraining {config} on {corpus_size} synthetic upstream images");
    let report = pretrain(&rt, &config, &mut params, &corpus, &pcfg)?;
    println!("pretrain final loss: {:.4}", report.final_loss);
    let out = PathBuf::from(args.str_or("out", &format!("ckpt_{config}.bin")));
    params.save(&out)?;
    println!("saved checkpoint to {out:?}");
    Ok(())
}

fn load_backbone(args: &Args, rt: &Runtime, config: &str) -> Result<ParamStore> {
    let cfg = rt.manifest().config(config)?;
    let ckpt = args.str_or("ckpt", &format!("ckpt_{config}.bin"));
    let path = PathBuf::from(&ckpt);
    if path.exists() {
        info!("loading backbone from {path:?}");
        ParamStore::load(&path, cfg)
    } else if args.flag("no-pretrain") {
        info!("using random backbone (--no-pretrain)");
        Ok(ParamStore::init(cfg, &mut Rng::new(args.u64_or("seed", 42))))
    } else {
        bail!("checkpoint {path:?} not found — run `taskedge pretrain` first \
               or pass --no-pretrain for a random backbone")
    }
}

fn cmd_finetune(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let config = args.str_or("config", "micro");
    let seed = args.u64_or("seed", 42);
    let task = synthvtab::task_by_name(&args.str_or("task", "caltech101"))?;
    let strategy = Strategy::parse(&args.str_or("strategy", "taskedge:k=8"))?;
    let backbone = load_backbone(args, &rt, &config)?;
    let cfg = rt.manifest().config(&config)?;
    let batch = rt.manifest().batch;

    let n_train = args.usize_or("n-train", 1000);
    let n_eval = args.usize_or("n-eval", 200).div_ceil(batch) * batch;
    let (train, eval) = generate_task(task, cfg.image_size, n_train, n_eval,
                                      seed)?;

    let tcfg = TrainConfig {
        epochs: args.usize_or("epochs", 20),
        lr: args.f32_or("lr", 1e-3),
        weight_decay: args.f32_or("wd", 1e-4),
        seed,
        calib_batches: args.usize_or("calib-batches", 8),
        eval_every: args.usize_or("eval-every", 1),
        ..Default::default()
    };
    let mut session = FinetuneSession::new(&rt, &config, strategy.clone(), tcfg)?;
    let result = session.run(&backbone, &train, &eval, task.name)?;

    println!(
        "task {} strategy {}: top1 {:.3} top5 {:.3} trainable {:.4}% \
         (calib {:.0} ms, train {:.0} ms)",
        task.name,
        strategy.name(),
        result.record.best_top1(),
        result.record.best_top5(),
        result.trainable_frac * 100.0,
        result.calib_wall_ms,
        result.train_wall_ms,
    );
    if let Some(out) = args.get("delta-out") {
        let path = PathBuf::from(out);
        result.delta.save(&path)?;
        let report = DeltaSizeReport::new(&result.delta, cfg);
        println!(
            "saved task delta to {path:?}: {} bytes ({:.3}% of the \
             {}-byte full checkpoint)",
            report.delta_bytes,
            report.ratio() * 100.0,
            report.full_bytes
        );
    }
    if let Some(log) = args.get("log") {
        let mut logger = JsonlLogger::create(&PathBuf::from(log))?;
        logger.log(&result.record.to_json())?;
    }
    Ok(())
}

/// Diff two full checkpoints into a sparse `TaskDelta` artifact — the
/// offline path for converting legacy full-store fine-tuning outputs into
/// hot-swappable serving deltas. Only the manifest is needed (no PJRT).
fn cmd_export_delta(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let manifest = taskedge::runtime::Manifest::load(&dir)?;
    let config = args.str_or("config", "micro");
    let cfg = manifest.config(&config)?;
    let base = PathBuf::from(
        args.get("base")
            .context("export-delta requires --base <backbone.bin>")?,
    );
    let tuned_path = PathBuf::from(
        args.get("tuned")
            .context("export-delta requires --tuned <finetuned.bin>")?,
    );
    let out = PathBuf::from(args.str_or("out", "task.delta"));
    let backbone = ParamStore::load(&base, cfg)?;
    let tuned = ParamStore::load(&tuned_path, cfg)?;
    let mut delta = TaskDelta::diff(&backbone, &tuned)?;
    delta.strategy = args.str_or("strategy", "export");
    delta.task = args.str_or("task", "");
    delta.save(&out)?;
    let report = DeltaSizeReport::new(&delta, cfg);
    println!(
        "wrote {out:?}: {} changed values in {} tensors, {} bytes \
         ({:.3}% of the {}-byte full checkpoint)",
        delta.num_values(),
        delta.sparse.len() + delta.dense.len(),
        report.delta_bytes,
        report.ratio() * 100.0,
        report.full_bytes
    );
    Ok(())
}

fn cmd_evaluate(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let config = args.str_or("config", "micro");
    let seed = args.u64_or("seed", 42);
    let task = synthvtab::task_by_name(&args.str_or("task", "caltech101"))?;
    let backbone = load_backbone(args, &rt, &config)?;
    let cfg = rt.manifest().config(&config)?;
    let batch = rt.manifest().batch;
    let n_eval = args.usize_or("n-eval", 192).div_ceil(batch) * batch;
    let (_, eval) = generate_task(task, cfg.image_size, 1, n_eval, seed)?;

    // zero-shot evaluation of the backbone (fresh head = chance level)
    let spec = rt.manifest().artifact_for("eval", &config)?.clone();
    let mut loss = 0.0;
    let mut top1 = 0.0;
    for start in (0..eval.n).step_by(batch) {
        let ids: Vec<usize> = (start..start + batch).collect();
        let (images, labels) = eval.batch(&ids)?;
        let binder = taskedge::runtime::IoBinder::new(&spec);
        let inputs = binder.bind(|io| {
            if let Some(p) = io.name.strip_prefix("param:") {
                Ok(backbone.get(p)?.clone())
            } else if io.name == "images" {
                Ok(images.clone())
            } else if io.name == "labels" {
                Ok(labels.clone())
            } else {
                bail!("unexpected eval input {}", io.name)
            }
        })?;
        let outputs = rt.execute(&spec.name, &inputs)?;
        loss += binder.output(&outputs, "loss_sum")?.item_f32()? as f64;
        top1 += binder.output(&outputs, "n_correct")?.item_f32()? as f64;
    }
    println!(
        "task {}: eval loss {:.4}, top1 {:.3} over {} examples",
        task.name,
        loss / eval.n as f64,
        top1 / eval.n as f64,
        eval.n
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg_path = PathBuf::from(
        args.get("config").context("run requires --config <file.json>")?,
    );
    let ecfg = taskedge::config::ExperimentConfig::load(&cfg_path)?;
    let rt = Arc::new(load_runtime(args)?);
    let mcfg = rt.manifest().config(&ecfg.model)?.clone();
    let batch = rt.manifest().batch;

    // backbone: checkpoint if present, else pretrain per the config
    let ckpt = PathBuf::from(args.str_or("ckpt", &format!("ckpt_{}.bin", ecfg.model)));
    let backbone = if ckpt.exists() {
        ParamStore::load(&ckpt, &mcfg)?
    } else {
        info!("pretraining backbone per config ({} steps)", ecfg.pretrain.steps);
        let corpus = upstream_corpus(mcfg.image_size, mcfg.num_classes,
                                     ecfg.corpus_size, ecfg.seed)?;
        let mut params = ParamStore::init(&mcfg, &mut Rng::new(ecfg.seed));
        pretrain(&rt, &ecfg.model, &mut params, &corpus, &ecfg.pretrain)?;
        params.save(&ckpt)?;
        params
    };

    let n_eval = ecfg.n_eval.div_ceil(batch) * batch;
    let jobs: Vec<Job> = ecfg
        .jobs
        .iter()
        .map(|j| {
            Ok(Job {
                task: synthvtab::task_by_name(&j.task)?.clone(),
                strategy: j.strategy.clone(),
                train_cfg: ecfg.train.clone(),
                n_train: ecfg.n_train,
                n_eval,
            })
        })
        .collect::<Result<_>>()?;
    let devices = ecfg
        .devices
        .iter()
        .map(|d| {
            taskedge::edge::profiles::profile_by_name(d).with_context(|| {
                format!(
                    "unknown device {d:?} in {} (have: {:?})",
                    cfg_path.display(),
                    DEVICE_PROFILES.iter().map(|p| p.name).collect::<Vec<_>>()
                )
            })
        })
        .collect::<Result<_>>()?;
    let fleet = Fleet::new(devices);
    let reports = fleet.run(rt, &ecfg.model, Arc::new(backbone), jobs,
                            ecfg.seed)?;

    let mut t = Table::new(
        &format!("experiment {}", cfg_path.display()),
        &["task", "strategy", "device", "top1", "top5", "train %", "wall ms"],
    );
    let mut logger = ecfg
        .log_path
        .as_ref()
        .map(|p| JsonlLogger::create(&PathBuf::from(p)))
        .transpose()?;
    for r in &reports {
        t.row(vec![
            r.task.clone(),
            r.strategy.clone(),
            r.device.clone(),
            format!("{:.3}", r.top1),
            format!("{:.3}", r.top5),
            format!("{:.4}", r.trainable_frac * 100.0),
            format!("{:.0}", r.wall_ms),
        ]);
        if let Some(log) = logger.as_mut() {
            log.log(&taskedge::util::json::Json::obj(vec![
                ("task", r.task.as_str().into()),
                ("strategy", r.strategy.as_str().into()),
                ("device", r.device.as_str().into()),
                ("top1", r.top1.into()),
                ("top5", r.top5.into()),
                ("trainable_frac", r.trainable_frac.into()),
                ("wall_ms", r.wall_ms.into()),
            ]))?;
        }
    }
    t.print();
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use std::time::Duration;
    use taskedge::metrics::{fmt_bytes, fmt_duration};
    use taskedge::serve::{DeviceBuilder, DeviceConfig, TaskConfig};

    let rt = Arc::new(load_runtime(args)?);
    let config = args.str_or("config", "micro");
    let seed = args.u64_or("seed", 42);
    // graceful shutdown: SIGINT/SIGTERM stops admitting new requests, the
    // in-flight ones drain, and the stats report still prints (exit 0)
    let stop = taskedge::util::signal::install();
    let backbone = Arc::new(load_backbone(args, &rt, &config)?);
    let cfg = rt.manifest().config(&config)?.clone();
    let batch = rt.manifest().batch;

    let task_names = args.str_or("tasks", "pets,dtd");
    let n_requests = args.usize_or("requests", 16 * batch);
    let mut tasks = Vec::new();
    for name in task_names.split(',') {
        tasks.push(synthvtab::task_by_name(name.trim())?);
    }
    let dcfg = DeviceConfig {
        linger: Duration::from_millis(args.u64_or("linger-ms", 2)),
        // one work-conserving pool for the whole device, not per task
        workers: args.usize_or("workers", 2),
        // the demo submits open-loop: make sure each queue can absorb its
        // whole round-robin share (+1 warmup) so the command's own
        // backpressure doesn't abort it at high --requests
        max_queue: args
            .usize_or("max-queue", 1024)
            .max(n_requests.div_ceil(tasks.len()) + 1),
    };

    // per-task fair-queueing weights: --weights pets=4,dtd=1 (default 1)
    let mut weights = std::collections::BTreeMap::new();
    if let Some(spec) = args.get("weights") {
        for part in spec.split(',') {
            let (task, w) = part.split_once('=').with_context(|| {
                format!("--weights entry {part:?} must be task=weight")
            })?;
            let w: f64 = w.trim().parse().with_context(|| {
                format!("--weights entry {part:?}: weight must be a number")
            })?;
            // a typo'd weight must not silently serve at the clamp floor
            if !w.is_finite() || w <= 0.0 {
                bail!(
                    "--weights entry {part:?}: weight must be a positive \
                     finite number"
                );
            }
            weights.insert(task.trim().to_string(), w);
        }
    }

    // every task rides the shared device executor (one compiled fwd graph,
    // per-task parameter literal sets); tasks with a --deltas entry serve
    // backbone + TaskDelta (the fine-tuned weights)
    let mut delta_paths = std::collections::BTreeMap::new();
    if let Some(spec) = args.get("deltas") {
        for part in spec.split(',') {
            let (task, path) = part.split_once('=').with_context(|| {
                format!("--deltas entry {part:?} must be task=file.delta")
            })?;
            delta_paths.insert(task.trim().to_string(),
                               PathBuf::from(path.trim()));
        }
    }
    let mut builder = DeviceBuilder::new(rt.clone(), &config, dcfg.clone());
    for task in &tasks {
        let tcfg = TaskConfig {
            weight: weights.remove(task.name).unwrap_or(1.0),
            max_queue: None,
        };
        match delta_paths.remove(task.name) {
            Some(path) => {
                let delta = TaskDelta::load(&path)?;
                // swapped file assignments must not silently serve another
                // task's weights (same guard as Router::swap_delta)
                if !delta.task.is_empty() && delta.task != task.name {
                    bail!(
                        "{path:?} is labeled for task {:?}, not {:?} — \
                         refusing to serve it under the wrong task",
                        delta.task,
                        task.name
                    );
                }
                info!("serve: task {} adapted from delta {path:?} \
                       ({} values, strategy {:?})",
                      task.name, delta.num_values(), delta.strategy);
                builder.add_task_from_delta(task.name, backbone.clone(),
                                            &delta, tcfg)?;
            }
            None => builder.add_task(task.name, backbone.clone(), tcfg)?,
        }
    }
    // a typo'd task name must not silently serve the unadapted backbone
    if !delta_paths.is_empty() {
        bail!(
            "--deltas names tasks that are not being served: {:?} \
             (serving: {})",
            delta_paths.keys().collect::<Vec<_>>(),
            task_names
        );
    }
    if let Some(unknown) = weights.keys().next() {
        bail!(
            "--weights names a task that is not being served: {unknown:?} \
             (serving: {task_names})"
        );
    }
    let router = builder.build()?;

    info!("serve: {} requests across {} tasks (batch {batch}, {} device \
           workers)",
          n_requests, tasks.len(), dcfg.workers);
    // the lightweight admin view: print aggregate Router::stats() every
    // --stats-interval seconds while the load runs (0 = off)
    let stats_interval = args.u64_or("stats-interval", 0);
    let stats_done = std::sync::atomic::AtomicBool::new(false);
    let (wall, timed) = std::thread::scope(|scope| -> Result<(f64, usize)> {
        // one thread blocks in run(); the executor spawns the device-wide
        // worker pool internally
        let runner = scope.spawn(|| router.run());
        if stats_interval > 0 {
            let router = &router;
            let done = &stats_done;
            scope.spawn(move || {
                let tick = Duration::from_millis(100);
                let mut since = Duration::ZERO;
                while !done.load(std::sync::atomic::Ordering::Relaxed) {
                    std::thread::sleep(tick);
                    since += tick;
                    if since < Duration::from_secs(stats_interval) {
                        continue;
                    }
                    since = Duration::ZERO;
                    let rstats = router.stats();
                    let st = &rstats.total;
                    let dv = &rstats.device;
                    println!(
                        "[stats] reqs {} batches {} rejected {} swaps {} \
                         (donated {}) | resident {} evict {} saved {} | \
                         queue p50 {} p95 {} p99 {} | exec p50 {} p95 {} p99 {}",
                        st.requests, st.batches, st.rejected, st.swaps,
                        dv.donations,
                        fmt_bytes(dv.resident_bytes),
                        dv.resident_evictions,
                        fmt_bytes(dv.upload_savings_bytes),
                        fmt_duration(st.queue.quantile(0.50)),
                        fmt_duration(st.queue.quantile(0.95)),
                        fmt_duration(st.queue.quantile(0.99)),
                        fmt_duration(st.execute.quantile(0.50)),
                        fmt_duration(st.execute.quantile(0.95)),
                        fmt_duration(st.execute.quantile(0.99)),
                    );
                }
            });
        }
        let drive = || -> Result<(f64, usize)> {
            // synthetic single-image request streams, one pool per task
            let mut pools = Vec::new();
            for task in &tasks {
                let (_, pool) = generate_task(task, cfg.image_size, 1,
                                              2 * batch, seed)?;
                pools.push(pool);
            }
            // warm compile before timing
            for (t, task) in tasks.iter().enumerate() {
                let isz = pools[t].image_numel();
                router
                    .submit(task.name, pools[t].images[..isz].to_vec())?
                    .recv_timeout(Duration::from_secs(300))?;
            }
            let t0 = std::time::Instant::now();
            let mut rxs = Vec::with_capacity(n_requests);
            for r in 0..n_requests {
                // SIGINT/SIGTERM: stop admitting, drain what was submitted
                if stop.load(std::sync::atomic::Ordering::SeqCst) {
                    info!("serve: shutdown requested after {r} of \
                           {n_requests} requests; draining");
                    break;
                }
                let t = r % tasks.len();
                let isz = pools[t].image_numel();
                let i = (r / tasks.len()) % pools[t].n;
                let img = pools[t].images[i * isz..(i + 1) * isz].to_vec();
                rxs.push(router.submit(tasks[t].name, img)?);
            }
            let timed = rxs.len();
            for rx in rxs {
                rx.recv_timeout(Duration::from_secs(300))?;
            }
            Ok((t0.elapsed().as_secs_f64(), timed))
        };
        let result = drive();
        stats_done.store(true, std::sync::atomic::Ordering::Relaxed);
        router.shutdown();
        // surface a server-side failure (the root cause) ahead of the
        // client-side timeout it produced
        runner
            .join()
            .map_err(|_| anyhow::anyhow!("server thread panicked"))??;
        result
    })?;

    let stats = router.stats();
    let mut t = Table::new(
        "serving report",
        &["task", "reqs", "batches", "padded", "rejected", "swaps",
          "queue p50", "queue p99", "exec p50", "exec p99"],
    );
    let mut row = |label: &str, st: &taskedge::serve::ServerStats| {
        t.row(vec![
            label.to_string(),
            st.requests.to_string(),
            st.batches.to_string(),
            st.padded_rows.to_string(),
            st.rejected.to_string(),
            st.swaps.to_string(),
            fmt_duration(st.queue.quantile(0.50)),
            fmt_duration(st.queue.quantile(0.99)),
            fmt_duration(st.execute.quantile(0.50)),
            fmt_duration(st.execute.quantile(0.99)),
        ]);
    };
    for (task, st) in &stats.per_task {
        row(task, st);
    }
    row("TOTAL", &stats.total);
    t.print();
    // the table includes one untimed warmup request per task; the
    // throughput denominator is timed requests only
    println!("throughput: {:.0} img/s over {timed} timed requests \
              (table includes {} warmup)",
             timed as f64 / wall.max(1e-9), tasks.len());
    let d = &stats.device;
    println!(
        "device: {} workers, {} sub-batches ({} cross-task switches, {} \
         DRR rounds), {:.1}% rows padded",
        d.workers,
        d.dispatches,
        d.task_switches,
        d.drr_rounds,
        100.0 * stats.total.padded_rows as f64
            / (stats.total.batches * batch).max(1) as f64
    );
    let rs = rt.stats();
    println!(
        "param literals: {} set builds ({} converted: start + full swaps \
         only), {} cache hits, {} bound from cache across batches",
        rs.param_prepares,
        fmt_bytes(rs.param_prepare_bytes),
        rs.param_cache_hits,
        fmt_bytes(rs.param_reuse_bytes)
    );
    println!(
        "device residency: {} resident now ({} uploads, {} evictions), \
         {} donated swaps ({} refreshed in place), {} h2d saved vs \
         literal re-binding",
        fmt_bytes(rs.resident_bytes),
        rs.resident_prepares,
        rs.resident_evictions,
        rs.donations,
        fmt_bytes(rs.donated_refresh_bytes),
        fmt_bytes(rs.h2d_resident_bytes)
    );
    Ok(())
}

/// `taskedge check` — static contract analysis over an artifact directory.
/// Needs only the manifest (and optional delta files): no PJRT, no device,
/// no HLO loading. Exit codes are part of the interface (see docs/check.md):
/// 0 = clean (warnings allowed), 1 = error findings, 2 = tool failure.
fn cmd_check(args: &Args) -> Result<()> {
    use taskedge::analysis::{check_dir, has_errors, render_human, render_json};

    let inner = || -> Result<Vec<taskedge::analysis::Finding>> {
        let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
        let mut deltas: Vec<(String, PathBuf)> = Vec::new();
        if let Some(spec) = args.get("deltas") {
            for part in spec.split(',') {
                let (task, path) = part.split_once('=').with_context(|| {
                    format!("--deltas entry {part:?} must be task=file.delta")
                })?;
                deltas.push((task.trim().to_string(), PathBuf::from(path.trim())));
            }
        }
        Ok(check_dir(&dir, &deltas))
    };
    let findings = match inner() {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    if args.flag("json") {
        println!("{}", render_json(&findings));
    } else {
        print!("{}", render_human(&findings));
    }
    if has_errors(&findings) {
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_fleet(args: &Args) -> Result<()> {
    let rt = Arc::new(load_runtime(args)?);
    let config = args.str_or("config", "micro");
    let seed = args.u64_or("seed", 42);
    let backbone = Arc::new(load_backbone(args, &rt, &config)?);
    let batch = rt.manifest().batch;

    let devices = parse_devices(args)?;
    let jobs = fleet_jobs(args, batch, seed)?;
    info!("fleet: {} jobs across {} devices", jobs.len(), devices.len());
    let fleet = Fleet::new(devices);

    let faults = match args.get("fault-plan") {
        Some(spec) => FaultPlan::parse(spec, seed)?,
        None => FaultPlan::default(),
    };
    let rcfg = round_config(args, seed, faults);
    let round = fleet.run_round(rt.clone(), &config, backbone, jobs, &rcfg)?;

    print_round_report("fleet report", &round);
    let s = &round.summary;
    if !s.quorum_met {
        bail!(
            "quorum missed: {} accepted of {} required",
            s.accepted, s.quorum_required
        );
    }
    Ok(())
}

/// Shared by `fleet` and `fleet-serve`: the device pool from `--devices`,
/// with unknown names a CLI error listing the valid profiles.
fn parse_devices(args: &Args) -> Result<Vec<&'static taskedge::edge::DeviceProfile>> {
    let names = args.str_or("devices",
                            "jetson-orin-nano,jetson-nano,phone-flagship");
    names
        .split(',')
        .map(|n| {
            taskedge::edge::profiles::profile_by_name(n.trim())
                .with_context(|| format!("unknown device {n:?} (have: {:?})",
                    DEVICE_PROFILES.iter().map(|p| p.name).collect::<Vec<_>>()))
        })
        .collect()
}

/// Shared by `fleet` and `fleet-serve`: the `--tasks` × `--strategies`
/// job grid, `n_eval` rounded up to whole batches.
fn fleet_jobs(args: &Args, batch: usize, seed: u64) -> Result<Vec<Job>> {
    let task_names = args.str_or("tasks", "caltech101,dtd,pets");
    let strat_names = args.str_or("strategies", "taskedge:k=8,linear,bitfit");
    let tcfg = TrainConfig {
        epochs: args.usize_or("epochs", 5),
        lr: args.f32_or("lr", 1e-3),
        seed,
        ..Default::default()
    };
    let n_eval = args.usize_or("n-eval", 192).div_ceil(batch) * batch;
    let mut jobs = Vec::new();
    for t in task_names.split(',') {
        let task = synthvtab::task_by_name(t.trim())?;
        for s in strat_names.split(',') {
            jobs.push(Job {
                task: task.clone(),
                strategy: Strategy::parse(s.trim())?,
                train_cfg: tcfg.clone(),
                n_train: args.usize_or("n-train", 320),
                n_eval,
            });
        }
    }
    Ok(jobs)
}

/// Shared by `fleet` and `fleet-serve`: the round engine settings from the
/// common CLI flags.
fn round_config(args: &Args, seed: u64, faults: FaultPlan) -> RoundConfig {
    RoundConfig {
        seed,
        max_attempts: args.usize_or("max-attempts", 3) as u32,
        backoff_ms: args.u64_or("backoff-ms", 50),
        job_timeout_ms: args.u64_or("job-timeout-ms", 0),
        train_deadline_ms: args.u64_or("round-deadline-ms", 0),
        quorum: args.f64_or("quorum", 1.0),
        delta_dir: args.get("delta-dir").map(PathBuf::from),
        resume: args.flag("resume"),
        faults,
        ..RoundConfig::default()
    }
}

fn print_round_report(title: &str, round: &taskedge::coordinator::RoundReport) {
    let mut t = Table::new(
        title,
        &["task", "strategy", "device", "status", "tries", "req MB", "top1",
          "train %", "delta KB", "wall ms", "sim J"],
    );
    for r in &round.reports {
        t.row(vec![
            r.task.clone(),
            r.strategy.clone(),
            r.device.clone(),
            r.status.name().to_string(),
            r.attempts.to_string(),
            format!("{:.0}", r.required_mb),
            format!("{:.3}", r.top1),
            format!("{:.4}", r.trainable_frac * 100.0),
            format!("{:.1}", r.delta_bytes as f64 / 1024.0),
            format!("{:.0}", r.wall_ms),
            format!("{:.1}", r.sim_energy_j),
        ]);
    }
    t.print();

    let s = &round.summary;
    info!(
        "round: {} accepted / {} dropped / {} not admitted ({} replayed) | \
         retries {} reassigned {} rejected uploads {} panics {} | \
         quorum {} ({} required) | {:.0} ms",
        s.accepted,
        s.dropped,
        s.not_admitted,
        s.replayed,
        s.retries,
        s.reassigned,
        s.rejected_uploads,
        s.panics,
        if s.quorum_met { "met" } else { "MISSED" },
        s.quorum_required,
        s.wall_ms,
    );
    if !s.dead_devices.is_empty() {
        info!("round: dead devices: {}", s.dead_devices.join(", "));
    }
}

/// `taskedge fleet-serve` — run one networked round as the coordinator
/// daemon: bind, rendezvous with remote participants, then drive the same
/// phased round engine the in-process `fleet` command uses, with
/// [`taskedge::net::NetRunner`] routing work over TCP.
fn cmd_fleet_serve(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", 42);
    let sim = args.flag("sim");
    let config = args.str_or("config", if sim { "sim" } else { "micro" });
    let bind = args.str_or("bind", "127.0.0.1:7700");
    let generation = args.u64_or("generation", 1);
    serve_round(args, &bind, seed, &config, generation, false)
}

/// The coordinator round shared by `fleet-serve` (a fresh primary) and a
/// promoted `standby` (which forces `resume` and bumps the generation):
/// bind, rendezvous, drive the round engine over [`NetRunner`], shipping
/// every journal entry to an attached standby as it is written.
fn serve_round(
    args: &Args,
    bind: &str,
    seed: u64,
    config: &str,
    generation: u64,
    force_resume: bool,
) -> Result<()> {
    use std::sync::atomic::Ordering;
    use std::time::Duration;
    use taskedge::coordinator::{run_round, SimRunner};
    use taskedge::net::{FleetServer, NetConfig, NetRunner, NetState};

    let stop = taskedge::util::signal::install();
    let sim = args.flag("sim");

    // sim mode runs the synthetic manifest with no artifacts and streams
    // no backbone; real mode streams the checkpoint to participants
    let (manifest, backbone_bytes) = if sim {
        (SimRunner::new(seed)?.manifest().clone(), None)
    } else {
        let rt = Arc::new(load_runtime(args)?);
        let backbone = load_backbone(args, &rt, config)?;
        (rt.manifest().clone(), Some(backbone.to_bytes()?))
    };
    let batch = manifest.batch;
    let devices = parse_devices(args)?;
    let jobs = fleet_jobs(args, batch, seed)?;
    let faults = match args.get("fault-plan") {
        Some(spec) => FaultPlan::parse(spec, seed)?,
        None => FaultPlan::default(),
    };

    let state = NetState::new(NetConfig {
        config_name: config.to_string(),
        seed,
        heartbeat_timeout_ms: args.u64_or("heartbeat-timeout-ms", 3_000),
        faults: faults.clone(),
        backbone: backbone_bytes,
        generation,
    });
    let mut server = FleetServer::start(bind, state.clone())?;
    let n = args.usize_or("participants", devices.len());
    info!(
        "fleet-serve: waiting for {n} participant(s) on {} \
         ({} jobs across {} devices)",
        server.addr,
        jobs.len(),
        devices.len()
    );
    let joined = server.await_participants(
        n,
        Duration::from_millis(args.u64_or("join-timeout-ms", 60_000)),
    )?;
    info!("fleet-serve: attached: {}", joined.join(", "));

    let mut rcfg = round_config(args, seed, faults);
    rcfg.stop = Some(stop.clone());
    rcfg.resume = rcfg.resume || force_resume;
    // every journal entry is offered to the attached standby (a no-op
    // until one attaches); the accept path blocks on its fsync'd ack
    rcfg.shipper = Some(state.journal_shipper());
    let runner = NetRunner::new(state, manifest.clone());
    let round = run_round(&manifest, &devices, &jobs, &runner, &rcfg)?;
    server.shutdown();

    print_round_report("fleet-serve report", &round);
    let s = &round.summary;
    if !s.quorum_met {
        // a requested shutdown legitimately ends the round under quorum;
        // that is a clean exit, not a failure
        if stop.load(Ordering::SeqCst) {
            info!(
                "fleet-serve: shutdown requested; exited with {} of {} \
                 required accepts",
                s.accepted, s.quorum_required
            );
            return Ok(());
        }
        bail!(
            "quorum missed: {} accepted of {} required",
            s.accepted, s.quorum_required
        );
    }
    Ok(())
}

/// `taskedge participate` — join a coordinator as a remote fleet
/// participant and serve assigned jobs until the round (or the
/// coordinator) finishes.
fn cmd_participate(args: &Args) -> Result<()> {
    use taskedge::coordinator::{JobRunner, SessionRunner, SimRunner};
    use taskedge::net::{participate, ParticipantOpts};

    taskedge::util::signal::install();
    let seed = args.u64_or("seed", 42);
    let faults = match args.get("fault-plan") {
        Some(spec) => FaultPlan::parse(spec, seed)?,
        None => FaultPlan::default(),
    };
    let opts = ParticipantOpts {
        addr: args.str_or("addr", "127.0.0.1:7700"),
        device: args
            .get("device")
            .context("participate requires --device <profile name>")?
            .to_string(),
        seed,
        backoff_ms: args.u64_or("backoff-ms", 200),
        max_reconnects: args.usize_or("max-reconnects", 8) as u32,
        once: args.flag("once"),
        heartbeat_ms: args.u64_or("heartbeat-ms", 0),
        faults,
    };

    let stats = if args.flag("sim") {
        participate(&opts, |welcome, _backbone| {
            Ok(Box::new(SimRunner::new(welcome.seed)?) as Box<dyn JobRunner>)
        })?
    } else {
        let rt = Arc::new(load_runtime(args)?);
        participate(&opts, move |welcome, backbone| {
            let cfg = rt.manifest().config(&welcome.config)?;
            let bytes = backbone.context(
                "coordinator streamed no backbone, but this participant is \
                 not in --sim mode",
            )?;
            let store = ParamStore::from_bytes(bytes, cfg)?;
            Ok(Box::new(SessionRunner::new(
                rt.clone(),
                &welcome.config,
                Arc::new(store),
                welcome.seed,
            )) as Box<dyn JobRunner>)
        })?
    };
    info!(
        "participate: {} uploads ({} from cache), {} warmups, {} failed \
         attempts, {} reconnects, {} round(s) served",
        stats.uploads,
        stats.reuploads,
        stats.warmups,
        stats.failures,
        stats.reconnects,
        stats.rounds
    );
    Ok(())
}

/// `taskedge standby` — the hot-standby coordinator: attach to the
/// primary, persist the shipped round journal (snapshot + live stream),
/// and when the primary's lease expires promote: install the journal,
/// bind the advertised address at generation + 1, and finish the round
/// through the engine's `--resume` replay.
fn cmd_standby(args: &Args) -> Result<()> {
    use taskedge::net::{install_shipped_journal, stand_by, StandbyOpts};

    taskedge::util::signal::install();
    let advertise = args.str_or("advertise", "127.0.0.1:7701");
    let delta_dir = PathBuf::from(args.get("delta-dir").context(
        "standby requires --delta-dir (the round's delta directory, where \
         the shipped journal is installed on promotion)",
    )?);
    let journal_path = args
        .get("journal")
        .map(PathBuf::from)
        .unwrap_or_else(|| delta_dir.join("standby.journal"));
    let opts = StandbyOpts {
        primary: args.str_or("primary", "127.0.0.1:7700"),
        advertise: advertise.clone(),
        journal_path,
        lease_ms: args.u64_or("lease-ms", 10_000),
        backoff_ms: args.u64_or("backoff-ms", 200),
        seed: args.u64_or("seed", 42),
    };
    let report = stand_by(&opts)?;
    info!(
        "standby: {} journal entries shipped ({} snapshot(s), {} \
         reconnect(s))",
        report.entries, report.snapshots, report.reconnects
    );
    if !report.promoted {
        info!("standby: primary shut down cleanly; standing down");
        return Ok(());
    }
    install_shipped_journal(&opts.journal_path, &delta_dir)?;
    let generation = report.generation + 1;
    info!(
        "standby: lease expired; promoting at {advertise} as generation \
         {generation} (seed {}, config {})",
        report.seed, report.config
    );
    // the promoted run inherits the primary's round identity from the
    // welcome, not from local flags — a mismatched seed would make the
    // replayed journal unverifiable
    serve_round(args, &advertise, report.seed, &report.config, generation, true)
}
