//! Minimal JSON substrate (serde is not available offline — DESIGN.md §2).
//!
//! Full RFC 8259 parser + serializer over an owned [`Json`] value tree.
//! Used for `artifacts/manifest.json`, golden vectors, run logs and configs.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

// hand-rolled (the offline dependency set has no thiserror): Display +
// Error give `?`/anyhow interop for the parse path
impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that errors with the missing key name.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Flatten a (nested) numeric array into f32s, row-major.
    pub fn as_f32_flat(&self) -> Vec<f32> {
        let mut out = Vec::new();
        fn rec(v: &Json, out: &mut Vec<f32>) {
            match v {
                Json::Num(n) => out.push(*n as f32),
                Json::Arr(a) => a.iter().for_each(|x| rec(x, out)),
                _ => {}
            }
        }
        rec(self, &mut out);
        out
    }

    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    pub fn arr_str(v: &[String]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Str(x.clone())).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            // RFC 8259 leaves duplicate-key behavior implementation-defined;
            // a BTreeMap insert would silently keep the LAST value, which for
            // manifest configs means a duplicate name shadows an earlier one
            // without any signal. Every legitimate producer we parse (the AOT
            // compiler, our own serializer) emits unique keys, so reject.
            if m.contains_key(&k) {
                return Err(self.err(&format!("duplicate key {k:?}")));
            }
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or_else(|| self.err("eof in string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or_else(|| self.err("eof escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let cp = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            // surrogate pair
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or_else(|| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(
                                        std::str::from_utf8(hex2)
                                            .map_err(|_| self.err("bad surrogate"))?,
                                        16,
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 6;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // Re-sync to char boundary for multibyte utf-8.
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.b.len() && self.b[end] & 0xC0 == 0x80 {
                        end += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        while self
            .peek()
            .map(|c| {
                c.is_ascii_digit()
                    || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            })
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Serialization
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if !n.is_finite() {
                    // RFC 8259 has no NaN/Infinity literal — `{n}` would
                    // print `NaN`, producing unparseable output (the
                    // skipped-epoch eval metrics bug). Serialize as null;
                    // readers map the null back to NaN (see
                    // `RunRecord::from_json`).
                    write!(f, "null")
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":[1,2.5,"s",true,null],"y":{"z":-3}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // regression: skipped-epoch eval metrics are f64::NAN and used to
        // print as the invalid literal `NaN`
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(Json::Num(v).to_string(), "null");
        }
        let j = Json::obj(vec![
            ("ok", 1.5.into()),
            ("skipped", f64::NAN.into()),
        ]);
        let text = j.to_string();
        assert_eq!(text, r#"{"ok":1.5,"skipped":null}"#);
        // and the output round-trips through the parser
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("skipped"), Some(&Json::Null));
        assert_eq!(back.get("ok").and_then(Json::as_f64), Some(1.5));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
    }

    #[test]
    fn rejects_duplicate_keys() {
        // duplicate config names in a manifest arrive as duplicate JSON
        // object keys; they must fail the parse, not last-writer-wins
        let err = Json::parse(r#"{"a":1,"a":2}"#).unwrap_err();
        assert!(err.msg.contains("duplicate key \"a\""), "{err}");
        assert!(Json::parse(r#"{"o":{"x":1,"x":1}}"#).is_err());
        // distinct keys still fine
        assert!(Json::parse(r#"{"a":1,"b":{"a":1}}"#).is_ok());
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn f32_flat() {
        let j = Json::parse("[[1,2],[3,4]]").unwrap();
        assert_eq!(j.as_f32_flat(), vec![1.0, 2.0, 3.0, 4.0]);
    }
}
