//! Tiny CLI argument substrate (clap is not available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args,
//! with typed getters and a generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw args (excluding argv[0]). `flag_names` lists boolean flags
    /// that do not consume a value.
    pub fn parse(raw: &[String], flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else if i + 1 < raw.len() {
                    out.options.insert(stripped.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env(flag_names: &[&str]) -> Args {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw, flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f32_or(&self, name: &str, default: f32) -> f32 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_mixed() {
        let a = Args::parse(
            &sv(&["run", "--k", "v", "--x=3", "--quiet", "pos2"]),
            &["quiet"],
        );
        assert_eq!(a.positional, sv(&["run", "pos2"]));
        assert_eq!(a.get("k"), Some("v"));
        assert_eq!(a.usize_or("x", 0), 3);
        assert!(a.flag("quiet"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn typed_defaults() {
        let a = Args::parse(&sv(&["--lr", "0.01"]), &[]);
        assert_eq!(a.f32_or("lr", 0.0), 0.01);
        assert_eq!(a.usize_or("missing", 7), 7);
    }

    #[test]
    fn trailing_option_without_value_is_flag() {
        let a = Args::parse(&sv(&["--end"]), &[]);
        assert!(a.flag("end"));
    }
}
