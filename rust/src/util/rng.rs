//! Deterministic RNG substrate (the `rand` crate is not available offline).
//!
//! SplitMix64 for seeding + xoshiro256** core, plus the distributions the
//! data generators and initializers need (uniform, normal via Box–Muller,
//! truncated normal, permutations, categorical).

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller output
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent stream (stable: hashes the label into the seed).
    pub fn fork(&mut self, label: &str) -> Rng {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Rng::new(self.next_u64() ^ h)
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Rejection-free (n << 2^64 bias negligible
    /// for our n, but use Lemire's method anyway).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal (Box–Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Truncated standard normal on [-2, 2] (rejection), scaled — matches
    /// the jax `truncated_normal` init used at L2.
    pub fn trunc_normal_f32(&mut self, std: f32) -> f32 {
        loop {
            let z = self.normal();
            if (-2.0..=2.0).contains(&z) {
                return std * z as f32;
            }
        }
    }

    /// Fill a vector with N(0, std) f32s.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32(0.0, std)).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.below(i + 1));
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn trunc_normal_in_range() {
        let mut r = Rng::new(4);
        for _ in 0..10_000 {
            let x = r.trunc_normal_f32(0.02);
            assert!(x.abs() <= 0.04 + 1e-6);
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(5);
        let mut p = r.permutation(50);
        p.sort_unstable();
        assert_eq!(p, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(6);
        let mut a = r.fork("a");
        let mut b = r.fork("b");
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
