//! Minimal SIGINT/SIGTERM handling without a signal crate (the offline
//! build has no `libc`/`signal-hook`; libstd already links the platform's
//! libc, so the raw `signal(2)` symbol is available for the asking).
//!
//! The handler does the only async-signal-safe thing worth doing: it sets
//! a process-wide `AtomicBool`. Long-running commands (`taskedge serve`,
//! `taskedge fleet-serve`) poll [`stop_requested`] — or hand the shared
//! flag to the round engine via `RoundConfig::stop` — and drain instead of
//! dying mid-batch. A second signal restores the default disposition, so
//! a stuck drain can still be killed with a repeat Ctrl-C.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::sync::OnceLock;

#[cfg(unix)]
const SIGINT: i32 = 2;
#[cfg(unix)]
const SIGTERM: i32 = 15;

#[cfg(unix)]
extern "C" {
    /// `signal(2)` from the platform libc libstd already links.
    fn signal(signum: i32, handler: usize) -> usize;
}

fn stop_cell() -> &'static Arc<AtomicBool> {
    static STOP: OnceLock<Arc<AtomicBool>> = OnceLock::new();
    STOP.get_or_init(|| Arc::new(AtomicBool::new(false)))
}

#[cfg(unix)]
extern "C" fn on_signal(signum: i32) {
    stop_cell().store(true, Ordering::SeqCst);
    // restore the default disposition: the *next* signal kills us, so an
    // operator is never locked out of a hung drain
    unsafe {
        signal(signum, 0);
    }
}

/// Install SIGINT/SIGTERM handlers (idempotent) and return the shared
/// stop flag. On non-unix targets this is just the flag — nothing ever
/// sets it asynchronously.
pub fn install() -> Arc<AtomicBool> {
    #[cfg(unix)]
    {
        static INSTALLED: AtomicBool = AtomicBool::new(false);
        if !INSTALLED.swap(true, Ordering::SeqCst) {
            unsafe {
                signal(SIGINT, on_signal as usize);
                signal(SIGTERM, on_signal as usize);
            }
        }
    }
    stop_cell().clone()
}

/// Has a termination signal arrived since [`install`]?
pub fn stop_requested() -> bool {
    stop_cell().load(Ordering::SeqCst)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn install_is_idempotent_and_flag_is_shared() {
        let a = install();
        let b = install();
        assert!(Arc::ptr_eq(&a, &b));
        // the flag is observable through both handles and the free fn
        // (restored afterwards so other tests see a clean state)
        a.store(true, Ordering::SeqCst);
        assert!(stop_requested());
        a.store(false, Ordering::SeqCst);
        assert!(!stop_requested());
    }
}
