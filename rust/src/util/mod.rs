//! Offline substrates: JSON, RNG, CLI, bench harness, property testing,
//! logging. These replace serde/rand/clap/criterion/proptest, which are not
//! available in the offline build environment (DESIGN.md §2).

pub mod bench;
pub mod cli;
pub mod hash;
pub mod json;
pub mod prop;
pub mod rng;
pub mod signal;

use std::sync::atomic::{AtomicU8, Ordering};

static LOG_LEVEL: AtomicU8 = AtomicU8::new(1); // 0=quiet 1=info 2=debug

pub fn set_log_level(level: u8) {
    LOG_LEVEL.store(level, Ordering::Relaxed);
}

pub fn log_enabled(level: u8) -> bool {
    LOG_LEVEL.load(Ordering::Relaxed) >= level
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::util::log_enabled(1) {
            eprintln!("[taskedge] {}", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if $crate::util::log_enabled(2) {
            eprintln!("[taskedge:debug] {}", format!($($arg)*));
        }
    };
}
