//! Content digests for the round journal (FNV-1a, 64-bit).
//!
//! The journal records a digest of every accepted delta file so `--resume`
//! can prove the bytes on disk are the bytes that were accepted. FNV-1a is
//! not cryptographic — it guards against truncation, torn writes, and
//! accidental edits, which is the failure model for a local journal (a
//! hostile uploader is repelled by `analysis::check_delta_file`, not by
//! the digest).

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// 64-bit FNV-1a over `bytes`.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Digest rendered the way the journal stores it (16 hex digits).
pub fn fnv1a64_hex(bytes: &[u8]) -> String {
    format!("{:016x}", fnv1a64(bytes))
}

/// Seed-mixing helper: fold a label into a base seed so independent
/// decisions (per job, per attempt) draw from independent streams.
pub fn seed_with(seed: u64, label: &str) -> u64 {
    fnv1a64(label.as_bytes()) ^ seed.wrapping_mul(0x9e3779b97f4a7c15)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // canonical FNV-1a test vectors
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn hex_is_16_digits_and_stable() {
        let h = fnv1a64_hex(b"taskedge");
        assert_eq!(h.len(), 16);
        assert_eq!(h, fnv1a64_hex(b"taskedge"));
    }

    #[test]
    fn seed_with_separates_labels() {
        let a = seed_with(42, "panic:0");
        let b = seed_with(42, "panic:1");
        let c = seed_with(43, "panic:0");
        assert_ne!(a, b);
        assert_ne!(a, c);
    }
}
