//! Property-testing substrate (proptest is not available offline).
//!
//! `check` runs a property over `cases` randomized inputs drawn from a
//! caller-supplied generator; on failure it reports the seed so the case
//! reproduces deterministically. Used by the masking / coordinator /
//! data-pipeline invariant tests.

use super::rng::Rng;

/// Run `prop` over `cases` random inputs. Panics with the failing seed.
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    let base = 0x7a5c_ed9e_u64;
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64 * 0x9E37_79B9);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed on case {case} (seed {seed:#x}): \
                 {msg}\ninput: {input:?}"
            );
        }
    }
}

/// Convenience: assert-style equality with context inside properties.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add-commutes", 50, |r| (r.below(100), r.below(100)), |&(a, b)| {
            ensure(a + b == b + a, "addition must commute")
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_reports_seed() {
        check("always-fails", 5, |r| r.below(10), |_| Err("nope".into()));
    }
}
