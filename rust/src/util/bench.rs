//! Bench harness substrate (criterion is not available offline).
//!
//! `cargo bench` runs each `[[bench]]` target with `harness = false`; the
//! targets call into this module. Provides warmup + timed iterations with
//! mean / p50 / p95 and a table renderer shared with the paper-figure
//! benches.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns / 1e9)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let mut sorted = samples.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let stats = BenchStats {
        name: name.to_string(),
        iters,
        mean_ns: samples.iter().sum::<f64>() / iters as f64,
        p50_ns: sorted[iters / 2],
        p95_ns: sorted[((iters as f64 * 0.95) as usize).min(iters - 1)],
        min_ns: sorted[0],
    };
    println!(
        "bench {:40} mean {:>12} p50 {:>12} p95 {:>12} ({} iters)",
        stats.name,
        fmt_ns(stats.mean_ns),
        fmt_ns(stats.p50_ns),
        fmt_ns(stats.p95_ns),
        iters
    );
    stats
}

/// Plain-text table renderer used by the paper-reproduction benches.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs() {
        let s = bench("noop", 1, 10, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.iters, 10);
        assert!(s.mean_ns >= 0.0);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new("t", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("== t =="));
        assert!(r.contains("bb"));
    }
}
