//! Shared experiment harness for examples and paper-reproduction benches:
//! backbone setup (pretrain once, cache to disk), task sessions over a
//! (task × strategy) grid, and table assembly.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::{pretrain, FinetuneSession, PretrainConfig,
                         SessionResult, TrainConfig};
use crate::data::{generate_task, task_by_name, upstream_corpus};
use crate::runtime::Runtime;
use crate::peft::Strategy;
use crate::util::rng::Rng;
use crate::vit::ParamStore;

/// Environment knob: benches run a scaled-down grid by default; export
/// `TASKEDGE_FULL=1` to run at paper scale (1000 train examples, more
/// epochs — slow on CPU PJRT).
pub fn full_scale() -> bool {
    std::env::var("TASKEDGE_FULL").map(|v| v == "1").unwrap_or(false)
}

pub struct Experiment {
    pub rt: Arc<Runtime>,
    pub config: String,
    pub backbone: ParamStore,
    pub seed: u64,
}

impl Experiment {
    /// Load the runtime and obtain a pretrained backbone: reuses the
    /// cached checkpoint at `<artifacts>/backbone_<config>.bin` when
    /// present, otherwise pretrains on the synthetic upstream corpus and
    /// caches the result.
    pub fn setup(
        artifacts: &Path,
        config: &str,
        pretrain_steps: usize,
        seed: u64,
    ) -> Result<Experiment> {
        let rt = Arc::new(Runtime::load(artifacts)?);
        let cfg = rt.manifest().config(config)?.clone();
        let ckpt = artifacts.join(format!("backbone_{config}.bin"));
        let backbone = if ckpt.exists() {
            crate::info!("harness: loading cached backbone {ckpt:?}");
            ParamStore::load(&ckpt, &cfg)?
        } else {
            crate::info!(
                "harness: pretraining backbone ({pretrain_steps} steps) \
                 -> {ckpt:?}"
            );
            let corpus =
                upstream_corpus(cfg.image_size, cfg.num_classes, 2048, seed)?;
            let mut params = ParamStore::init(&cfg, &mut Rng::new(seed));
            let pcfg = PretrainConfig {
                steps: pretrain_steps,
                seed,
                ..Default::default()
            };
            pretrain(&rt, config, &mut params, &corpus, &pcfg)?;
            params.save(&ckpt).context("caching backbone")?;
            params
        };
        Ok(Experiment { rt, config: config.to_string(), backbone, seed })
    }

    /// Default artifacts dir: `./artifacts` (works from the repo root).
    pub fn default_artifacts() -> PathBuf {
        PathBuf::from(
            std::env::var("TASKEDGE_ARTIFACTS")
                .unwrap_or_else(|_| "artifacts".to_string()),
        )
    }

    /// Round an eval-set size up to a multiple of the AOT batch.
    pub fn eval_size(&self, want: usize) -> usize {
        let b = self.rt.manifest().batch;
        want.div_ceil(b) * b
    }

    /// Run one fine-tuning session on a SynthVTAB task.
    pub fn run_task(
        &self,
        task_name: &str,
        strategy: Strategy,
        train_cfg: TrainConfig,
        n_train: usize,
        n_eval: usize,
    ) -> Result<SessionResult> {
        let task = task_by_name(task_name)?;
        let cfg = self.rt.manifest().config(&self.config)?;
        let (train, eval) = generate_task(
            task,
            cfg.image_size,
            n_train,
            self.eval_size(n_eval),
            self.seed,
        )?;
        let mut session = FinetuneSession::new(
            &self.rt,
            &self.config,
            strategy,
            train_cfg,
        )?;
        session.run(&self.backbone, &train, &eval, task.name)
    }
}

/// Standard small/large experiment scales for the benches.
pub struct Scale {
    pub epochs: usize,
    pub n_train: usize,
    pub n_eval: usize,
    pub pretrain_steps: usize,
}

pub fn bench_scale() -> Scale {
    if full_scale() {
        Scale { epochs: 20, n_train: 1000, n_eval: 208, pretrain_steps: 4000 }
    } else {
        // pretraining needs multiple corpus epochs to give the backbone
        // transferable features (see EXPERIMENTS.md); the checkpoint is
        // cached under artifacts/ so the cost is paid once per config.
        Scale { epochs: 4, n_train: 256, n_eval: 96, pretrain_steps: 1500 }
    }
}
