//! Background batch prefetch for the training hot loop.
//!
//! Batch assembly (shuffled index draw + gathering `batch × H × W × C`
//! floats into an artifact-shaped tensor) is pure host work that the old
//! session loop ran serially between device executions. [`Prefetcher`]
//! moves it to a worker thread behind a bounded channel sized for double
//! buffering: while the device executes step *t*, the worker assembles the
//! batch for step *t+1*. The consumer blocks only when the device outruns
//! batch assembly.
//!
//! Determinism: the worker draws ids from `Batcher::new(n, batch, seed)` —
//! exactly the stream the inline path used — so training results are
//! bit-identical with and without prefetching (asserted by the unit tests
//! below and by `tests/integration_prepared.rs` end to end).

use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::runtime::HostTensor;

use super::{Batcher, Dataset};

/// Bounded lookahead. 2 is classic double buffering: one assembled batch
/// waiting while the next is being built; deeper queues only add memory.
const DEPTH: usize = 2;

/// A worker thread producing `(images, labels)` training batches ahead of
/// consumption. Created per training run, bounded to [`DEPTH`] batches in
/// flight, and joined on drop (the drop path never deadlocks: closing the
/// receiver unblocks a worker parked on a full channel).
pub struct Prefetcher {
    rx: Option<Receiver<Result<(HostTensor, HostTensor)>>>,
    worker: Option<JoinHandle<()>>,
}

impl Prefetcher {
    /// Spawn a worker producing exactly `total` batches from the id stream
    /// of `Batcher::new(dataset.n, batch, seed)`. The dataset is cloned
    /// into the worker once — O(dataset) up front against O(batch) per
    /// step saved from the hot loop for the rest of the run.
    pub fn spawn(dataset: &Dataset, batch: usize, seed: u64, total: usize) -> Prefetcher {
        let (tx, rx) = sync_channel(DEPTH);
        let data = dataset.clone();
        let worker = std::thread::spawn(move || {
            let mut batcher = Batcher::new(data.n, batch, seed);
            for _ in 0..total {
                let ids = batcher.next_batch();
                if tx.send(data.batch(&ids)).is_err() {
                    // consumer dropped early (session error path): stop
                    return;
                }
            }
        });
        Prefetcher { rx: Some(rx), worker: Some(worker) }
    }

    /// Spawn a worker producing the eval set's exact sequential chunks
    /// (`0..batch`, `batch..2*batch`, ...) — the same batches the inline
    /// eval pass assembles, so metrics are bit-identical. A dense session
    /// spawns this at epoch start to overlap eval-batch assembly with the
    /// tail of the epoch's train steps (bounded to [`DEPTH`] lookahead).
    pub fn spawn_eval(dataset: &Dataset, batch: usize) -> Prefetcher {
        let (tx, rx) = sync_channel(DEPTH);
        let data = dataset.clone();
        let worker = std::thread::spawn(move || {
            for start in (0..data.n).step_by(batch) {
                let ids: Vec<usize> =
                    (start..(start + batch).min(data.n)).collect();
                if tx.send(data.batch(&ids)).is_err() {
                    return;
                }
            }
        });
        Prefetcher { rx: Some(rx), worker: Some(worker) }
    }

    /// Receive the next prefetched batch. Errors after `total` batches
    /// were consumed, or if the worker terminated early.
    pub fn next(&mut self) -> Result<(HostTensor, HostTensor)> {
        self.rx
            .as_ref()
            .context("prefetcher already shut down")?
            .recv()
            .context("prefetch worker terminated early")?
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // closing the channel first unblocks a worker parked on send()
        drop(self.rx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_task, task_by_name};

    fn small_dataset() -> Dataset {
        let spec = task_by_name("syn-pets").unwrap();
        let (train, _) = generate_task(spec, 8, 20, 0, 3).unwrap();
        train
    }

    #[test]
    fn matches_inline_batcher_stream_exactly() {
        let train = small_dataset();
        let (batch, seed, total) = (4, 17u64, 11);
        let mut pf = Prefetcher::spawn(&train, batch, seed, total);
        let mut batcher = Batcher::new(train.n, batch, seed);
        for step in 0..total {
            let ids = batcher.next_batch();
            let (want_imgs, want_labs) = train.batch(&ids).unwrap();
            let (imgs, labs) = pf.next().unwrap();
            assert_eq!(imgs, want_imgs, "step {step}: images diverge");
            assert_eq!(labs, want_labs, "step {step}: labels diverge");
        }
        // the stream is exactly `total` long
        assert!(pf.next().is_err(), "prefetcher must stop after total batches");
    }

    #[test]
    fn batches_are_artifact_shaped() {
        let train = small_dataset();
        let mut pf = Prefetcher::spawn(&train, 4, 0, 2);
        let (imgs, labs) = pf.next().unwrap();
        assert_eq!(imgs.shape, vec![4, 8, 8, 3]);
        assert_eq!(labs.shape, vec![4]);
    }

    #[test]
    fn eval_prefetch_matches_sequential_chunks() {
        let train = small_dataset();
        let batch = 4;
        let mut pf = Prefetcher::spawn_eval(&train, batch);
        for start in (0..train.n).step_by(batch) {
            let ids: Vec<usize> = (start..start + batch).collect();
            let (want_imgs, want_labs) = train.batch(&ids).unwrap();
            let (imgs, labs) = pf.next().unwrap();
            assert_eq!(imgs, want_imgs, "chunk at {start}: images diverge");
            assert_eq!(labs, want_labs, "chunk at {start}: labels diverge");
        }
        assert!(pf.next().is_err(), "stream ends after the last chunk");
    }

    #[test]
    fn drop_while_worker_is_ahead_does_not_hang() {
        let train = small_dataset();
        // far more batches than the consumer takes: the worker will park
        // on the full channel; drop must still join promptly
        let mut pf = Prefetcher::spawn(&train, 4, 5, 10_000);
        let _ = pf.next().unwrap();
        drop(pf);
    }

    #[test]
    fn zero_total_yields_empty_stream() {
        let train = small_dataset();
        let mut pf = Prefetcher::spawn(&train, 4, 5, 0);
        assert!(pf.next().is_err());
    }
}
