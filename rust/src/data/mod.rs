//! Data substrates: SynthVTAB (the 19-task VTAB-1k analog, DESIGN.md §2),
//! the upstream pretraining corpus, batching, and background batch
//! prefetch for the training hot loop.

pub mod batcher;
pub mod prefetch;
pub mod synthvtab;

pub use batcher::Batcher;
pub use prefetch::Prefetcher;
pub use synthvtab::{generate_task, task_by_name, upstream_corpus, Dataset,
                    Group, TaskKind, TaskSpec, SYNTH_VTAB};
