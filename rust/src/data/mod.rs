//! Data substrates: SynthVTAB (the 19-task VTAB-1k analog, DESIGN.md §2),
//! the upstream pretraining corpus, and batching.

pub mod batcher;
pub mod synthvtab;

pub use batcher::Batcher;
pub use synthvtab::{generate_task, task_by_name, upstream_corpus, Dataset,
                    Group, TaskKind, TaskSpec, SYNTH_VTAB};
