//! SynthVTAB: procedurally generated 19-task analog of VTAB-1k.
//!
//! VTAB-1k itself is gated data (19 real vision datasets); SynthVTAB keeps
//! the benchmark *shape* (DESIGN.md §2): three groups (Natural /
//! Specialized / Structured), 1 000 train + 200 eval examples per task,
//! distribution shift from the upstream corpus, group-wise difficulty
//! ordering, and small-train-set overfitting pressure — the properties the
//! paper's evaluation exercises.
//!
//! Generators:
//! - **Prototype** tasks (Natural/Specialized): each class is a smooth
//!   random field prototype; samples add texture + jitter + noise.
//!   Specialized tasks shrink prototype separation and raise noise.
//! - **Structured** tasks are parametric visual-reasoning renders: object
//!   counting, blob distance, bar orientation, grid location, gradient
//!   azimuth / elevation — the SynthVTAB stand-ins for CLEVR / dSprites /
//!   SmallNORB / KITTI tasks.

use anyhow::{bail, Result};

use crate::runtime::HostTensor;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Group {
    Natural,
    Specialized,
    Structured,
}

impl Group {
    pub fn label(&self) -> &'static str {
        match self {
            Group::Natural => "Natural",
            Group::Specialized => "Specialized",
            Group::Structured => "Structured",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TaskKind {
    /// Class = smooth prototype field; knobs: separation & noise.
    Prototype { separation: f32, noise: f32, texture_freq: f32 },
    /// Count k in 1..=max blobs; label = k - 1.
    Count { max_objects: usize },
    /// Two blobs; label = binned centre distance.
    Distance { bins: usize },
    /// One oriented bar; label = angle bin.
    Orientation { bins: usize },
    /// One blob in a g×g grid; label = cell index.
    Location { grid: usize },
    /// Global luminance gradient direction; label = angle bin.
    Azimuth { bins: usize },
    /// Vertical gradient strength; label = bin.
    Elevation { bins: usize },
}

#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub name: &'static str,
    pub group: Group,
    pub classes: usize,
    pub kind: TaskKind,
    /// paper Table I column this task mirrors
    pub vtab_analog: &'static str,
}

/// The 19 tasks of VTAB-1k, mirrored. Class counts are capped at the model
/// head width (32); the analog column maps each to the paper's Table I.
pub const SYNTH_VTAB: &[TaskSpec] = &[
    // --- Natural (7)
    TaskSpec { name: "syn-cifar100", group: Group::Natural, classes: 20,
        kind: TaskKind::Prototype { separation: 0.85, noise: 0.50, texture_freq: 3.0 },
        vtab_analog: "CIFAR-100" },
    TaskSpec { name: "syn-caltech101", group: Group::Natural, classes: 16,
        kind: TaskKind::Prototype { separation: 1.25, noise: 0.30, texture_freq: 2.0 },
        vtab_analog: "Caltech101" },
    TaskSpec { name: "syn-dtd", group: Group::Natural, classes: 16,
        kind: TaskKind::Prototype { separation: 1.0, noise: 0.35, texture_freq: 6.0 },
        vtab_analog: "DTD" },
    TaskSpec { name: "syn-flowers102", group: Group::Natural, classes: 16,
        kind: TaskKind::Prototype { separation: 1.4, noise: 0.25, texture_freq: 2.5 },
        vtab_analog: "Flowers102" },
    TaskSpec { name: "syn-pets", group: Group::Natural, classes: 12,
        kind: TaskKind::Prototype { separation: 1.2, noise: 0.30, texture_freq: 2.0 },
        vtab_analog: "Pets" },
    TaskSpec { name: "syn-svhn", group: Group::Natural, classes: 10,
        kind: TaskKind::Prototype { separation: 0.9, noise: 0.55, texture_freq: 4.0 },
        vtab_analog: "SVHN" },
    TaskSpec { name: "syn-sun397", group: Group::Natural, classes: 20,
        kind: TaskKind::Prototype { separation: 0.8, noise: 0.45, texture_freq: 2.0 },
        vtab_analog: "Sun397" },
    // --- Specialized (4): narrow domains — low separation, sensor noise
    TaskSpec { name: "syn-camelyon", group: Group::Specialized, classes: 2,
        kind: TaskKind::Prototype { separation: 0.55, noise: 0.6, texture_freq: 5.0 },
        vtab_analog: "Patch Camelyon" },
    TaskSpec { name: "syn-eurosat", group: Group::Specialized, classes: 8,
        kind: TaskKind::Prototype { separation: 1.1, noise: 0.35, texture_freq: 1.5 },
        vtab_analog: "EuroSAT" },
    TaskSpec { name: "syn-resisc45", group: Group::Specialized, classes: 12,
        kind: TaskKind::Prototype { separation: 0.95, noise: 0.4, texture_freq: 2.5 },
        vtab_analog: "Resisc45" },
    TaskSpec { name: "syn-retinopathy", group: Group::Specialized, classes: 5,
        kind: TaskKind::Count { max_objects: 5 },
        vtab_analog: "Retinopathy" },
    // --- Structured (8): parametric reasoning
    TaskSpec { name: "syn-clevr-count", group: Group::Structured, classes: 8,
        kind: TaskKind::Count { max_objects: 8 },
        vtab_analog: "Clevr/count" },
    TaskSpec { name: "syn-clevr-dist", group: Group::Structured, classes: 6,
        kind: TaskKind::Distance { bins: 6 },
        vtab_analog: "Clevr/distance" },
    TaskSpec { name: "syn-dmlab", group: Group::Structured, classes: 6,
        kind: TaskKind::Distance { bins: 6 },
        vtab_analog: "DMLab" },
    TaskSpec { name: "syn-kitti-dist", group: Group::Structured, classes: 4,
        kind: TaskKind::Distance { bins: 4 },
        vtab_analog: "KITTI/distance" },
    TaskSpec { name: "syn-dsprites-loc", group: Group::Structured, classes: 16,
        kind: TaskKind::Location { grid: 4 },
        vtab_analog: "dSprites/loc" },
    TaskSpec { name: "syn-dsprites-ori", group: Group::Structured, classes: 16,
        kind: TaskKind::Orientation { bins: 16 },
        vtab_analog: "dSprites/ori" },
    TaskSpec { name: "syn-smallnorb-azi", group: Group::Structured, classes: 16,
        kind: TaskKind::Azimuth { bins: 16 },
        vtab_analog: "SmallNORB/azi" },
    TaskSpec { name: "syn-smallnorb-ele", group: Group::Structured, classes: 8,
        kind: TaskKind::Elevation { bins: 8 },
        vtab_analog: "SmallNORB/ele" },
];

pub fn task_by_name(name: &str) -> Result<&'static TaskSpec> {
    SYNTH_VTAB
        .iter()
        .find(|t| t.name == name || t.vtab_analog.eq_ignore_ascii_case(name))
        .ok_or_else(|| anyhow::anyhow!("unknown task {name:?}"))
}

// ---------------------------------------------------------------------------
// Dataset
// ---------------------------------------------------------------------------

/// In-memory image classification dataset ((N,H,W,C) f32 in [-1,1], i32
/// labels). VTAB-1k protocol: 1 000 train / 200 eval examples.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub n: usize,
    pub image_size: usize,
    pub channels: usize,
    pub classes: usize,
}

impl Dataset {
    pub fn image_numel(&self) -> usize {
        self.image_size * self.image_size * self.channels
    }

    /// Assemble a batch (images, labels) as artifact-ready tensors.
    /// Indices wrap modulo n so partial tail batches can be padded.
    pub fn batch(&self, ids: &[usize]) -> Result<(HostTensor, HostTensor)> {
        let isz = self.image_numel();
        let mut imgs = Vec::with_capacity(ids.len() * isz);
        let mut labs = Vec::with_capacity(ids.len());
        for &raw in ids {
            let i = raw % self.n;
            imgs.extend_from_slice(&self.images[i * isz..(i + 1) * isz]);
            labs.push(self.labels[i]);
        }
        Ok((
            HostTensor::from_f32(
                &[ids.len(), self.image_size, self.image_size, self.channels],
                imgs,
            )?,
            HostTensor::from_i32(&[ids.len()], labs)?,
        ))
    }
}

/// Generate the train/eval splits for a task (VTAB-1k: 1000/200).
pub fn generate_task(
    spec: &TaskSpec,
    image_size: usize,
    n_train: usize,
    n_eval: usize,
    seed: u64,
) -> Result<(Dataset, Dataset)> {
    let mut rng = Rng::new(seed ^ fnv(spec.name));
    let gen = TaskGenerator::new(spec, image_size, &mut rng)?;
    let train = gen.dataset(n_train, &mut rng.fork("train"));
    let eval = gen.dataset(n_eval, &mut rng.fork("eval"));
    Ok((train, eval))
}

fn fnv(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The upstream pretraining corpus: a 32-class prototype mixture spanning
/// all texture frequencies, so the backbone learns transferable features
/// that are nonetheless *shifted* from every downstream task.
pub fn upstream_corpus(
    image_size: usize,
    classes: usize,
    n: usize,
    seed: u64,
) -> Result<Dataset> {
    let spec = TaskSpec {
        name: "upstream",
        group: Group::Natural,
        classes,
        kind: TaskKind::Prototype { separation: 1.1, noise: 0.4, texture_freq: 3.0 },
        vtab_analog: "-",
    };
    let mut rng = Rng::new(seed ^ 0x5eed_c0de);
    let gen = TaskGenerator::new(&spec, image_size, &mut rng)?;
    Ok(gen.dataset(n, &mut rng.fork("corpus")))
}

// ---------------------------------------------------------------------------
// Generators
// ---------------------------------------------------------------------------

/// A smooth random field: sum of `k` random 2-D sinusoids per channel.
#[derive(Debug, Clone)]
struct Field {
    // (amp, fx, fy, phase) per component per channel
    comps: Vec<Vec<(f32, f32, f32, f32)>>,
}

impl Field {
    fn random(rng: &mut Rng, channels: usize, k: usize, freq: f32) -> Field {
        let comps = (0..channels)
            .map(|_| {
                (0..k)
                    .map(|_| {
                        (
                            rng.normal_f32(0.0, 1.0) / (k as f32).sqrt(),
                            rng.range(0.5, freq as f64) as f32,
                            rng.range(0.5, freq as f64) as f32,
                            rng.range(0.0, std::f64::consts::TAU) as f32,
                        )
                    })
                    .collect()
            })
            .collect();
        Field { comps }
    }

    fn sample(&self, x: f32, y: f32, c: usize) -> f32 {
        self.comps[c]
            .iter()
            .map(|&(a, fx, fy, ph)| {
                a * (std::f32::consts::TAU * (fx * x + fy * y) + ph).sin()
            })
            .sum()
    }
}

struct TaskGenerator<'a> {
    spec: &'a TaskSpec,
    size: usize,
    channels: usize,
    /// per-class prototype fields (Prototype tasks)
    prototypes: Vec<Field>,
    /// shared background texture
    background: Field,
}

impl<'a> TaskGenerator<'a> {
    fn new(spec: &'a TaskSpec, size: usize, rng: &mut Rng) -> Result<TaskGenerator<'a>> {
        if spec.classes == 0 {
            bail!("task {} has zero classes", spec.name);
        }
        let channels = 3;
        let (protos, bg_freq) = match spec.kind {
            TaskKind::Prototype { texture_freq, .. } => (spec.classes, texture_freq),
            _ => (0, 2.0),
        };
        let prototypes = (0..protos)
            .map(|c| {
                let mut prng = rng.fork(&format!("proto{c}"));
                Field::random(&mut prng, channels, 6, bg_freq)
            })
            .collect();
        let background = Field::random(&mut rng.fork("bg"), channels, 4, bg_freq);
        Ok(TaskGenerator { spec, size, channels, prototypes, background })
    }

    fn dataset(&self, n: usize, rng: &mut Rng) -> Dataset {
        let isz = self.size * self.size * self.channels;
        let mut images = Vec::with_capacity(n * isz);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            // Balanced labels: round-robin + shuffle-free (labels uniform).
            let class = i % self.spec.classes;
            let img = self.render(class, rng);
            images.extend_from_slice(&img);
            labels.push(class as i32);
        }
        Dataset {
            images,
            labels,
            n,
            image_size: self.size,
            channels: self.channels,
            classes: self.spec.classes,
        }
    }

    fn render(&self, class: usize, rng: &mut Rng) -> Vec<f32> {
        let s = self.size;
        let mut img = vec![0.0f32; s * s * self.channels];
        match self.spec.kind {
            TaskKind::Prototype { separation, noise, .. } => {
                let proto = &self.prototypes[class];
                // spatial jitter: prototype sampled at shifted coords
                let dx = rng.range(-0.15, 0.15) as f32;
                let dy = rng.range(-0.15, 0.15) as f32;
                for y in 0..s {
                    for x in 0..s {
                        let u = x as f32 / s as f32 + dx;
                        let v = y as f32 / s as f32 + dy;
                        for c in 0..self.channels {
                            let p = separation * proto.sample(u, v, c)
                                + 0.5 * self.background.sample(u, v, c)
                                + noise * rng.normal_f32(0.0, 1.0);
                            img[(y * s + x) * self.channels + c] = p.tanh();
                        }
                    }
                }
            }
            TaskKind::Count { max_objects } => {
                let count = class + 1; // label = count - 1
                debug_assert!(count <= max_objects);
                self.render_background(&mut img, rng, 0.2);
                for _ in 0..count {
                    self.draw_blob(
                        &mut img,
                        rng.range(0.15, 0.85) as f32,
                        rng.range(0.15, 0.85) as f32,
                        rng.range(0.05, 0.09) as f32,
                        [1.0, 0.8, 0.2],
                    );
                }
                self.add_noise(&mut img, rng, 0.15);
            }
            TaskKind::Distance { bins } => {
                self.render_background(&mut img, rng, 0.2);
                // distance in [0.1, 0.8] binned uniformly
                let d_lo = 0.1f32;
                let d_hi = 0.8f32;
                let bin_w = (d_hi - d_lo) / bins as f32;
                let d = d_lo + (class as f32 + rng.uniform_f32()) * bin_w;
                let cx = 0.5 + rng.range(-0.08, 0.08) as f32;
                let cy = 0.5 + rng.range(-0.08, 0.08) as f32;
                let ang = rng.range(0.0, std::f64::consts::TAU) as f32;
                let (ox, oy) = (d / 2.0 * ang.cos(), d / 2.0 * ang.sin());
                self.draw_blob(&mut img, cx - ox, cy - oy, 0.07, [1.0, 0.3, 0.3]);
                self.draw_blob(&mut img, cx + ox, cy + oy, 0.07, [0.3, 0.3, 1.0]);
                self.add_noise(&mut img, rng, 0.15);
            }
            TaskKind::Orientation { bins } => {
                self.render_background(&mut img, rng, 0.15);
                let bin_w = std::f32::consts::PI / bins as f32;
                let theta = (class as f32 + 0.2 + 0.6 * rng.uniform_f32()) * bin_w;
                self.draw_bar(&mut img, theta, rng);
                self.add_noise(&mut img, rng, 0.1);
            }
            TaskKind::Location { grid } => {
                self.render_background(&mut img, rng, 0.15);
                let (gx, gy) = (class % grid, class / grid);
                let cell = 1.0 / grid as f32;
                let cx = (gx as f32 + 0.25 + 0.5 * rng.uniform_f32()) * cell;
                let cy = (gy as f32 + 0.25 + 0.5 * rng.uniform_f32()) * cell;
                self.draw_blob(&mut img, cx, cy, 0.06, [0.9, 0.9, 0.9]);
                self.add_noise(&mut img, rng, 0.1);
            }
            TaskKind::Azimuth { bins } => {
                let bin_w = std::f32::consts::TAU / bins as f32;
                let phi = (class as f32 + 0.2 + 0.6 * rng.uniform_f32()) * bin_w;
                let (nx, ny) = (phi.cos(), phi.sin());
                let s_f = s as f32;
                for y in 0..s {
                    for x in 0..s {
                        let u = x as f32 / s_f - 0.5;
                        let v = y as f32 / s_f - 0.5;
                        let g = (u * nx + v * ny) * 2.0;
                        for c in 0..self.channels {
                            img[(y * s + x) * self.channels + c] =
                                (g + 0.2 * rng.normal_f32(0.0, 1.0)).tanh();
                        }
                    }
                }
            }
            TaskKind::Elevation { bins } => {
                // vertical gradient whose steepness encodes the class
                let steep = 0.3 + 2.0 * (class as f32 + 0.5) / bins as f32;
                let s_f = s as f32;
                for y in 0..s {
                    for x in 0..s {
                        let v = y as f32 / s_f - 0.5;
                        let g = (steep * v).tanh();
                        for c in 0..self.channels {
                            img[(y * s + x) * self.channels + c] =
                                g + 0.15 * rng.normal_f32(0.0, 1.0);
                        }
                    }
                }
            }
        }
        img
    }

    fn render_background(&self, img: &mut [f32], rng: &mut Rng, amp: f32) {
        let s = self.size;
        let dx = rng.range(-0.2, 0.2) as f32;
        for y in 0..s {
            for x in 0..s {
                let u = x as f32 / s as f32 + dx;
                let v = y as f32 / s as f32;
                for c in 0..self.channels {
                    img[(y * s + x) * self.channels + c] =
                        amp * self.background.sample(u, v, c);
                }
            }
        }
    }

    fn draw_blob(&self, img: &mut [f32], cx: f32, cy: f32, sigma: f32, color: [f32; 3]) {
        let s = self.size;
        for y in 0..s {
            for x in 0..s {
                let u = x as f32 / s as f32 - cx;
                let v = y as f32 / s as f32 - cy;
                let g = (-(u * u + v * v) / (2.0 * sigma * sigma)).exp();
                for c in 0..self.channels {
                    let px = &mut img[(y * s + x) * self.channels + c];
                    *px = (*px + g * color[c]).clamp(-1.0, 1.0);
                }
            }
        }
    }

    fn draw_bar(&self, img: &mut [f32], theta: f32, rng: &mut Rng) {
        let s = self.size;
        let cx = 0.5 + rng.range(-0.1, 0.1) as f32;
        let cy = 0.5 + rng.range(-0.1, 0.1) as f32;
        let (dx, dy) = (theta.cos(), theta.sin());
        let half_len = 0.3;
        let half_w = 0.04;
        for y in 0..s {
            for x in 0..s {
                let u = x as f32 / s as f32 - cx;
                let v = y as f32 / s as f32 - cy;
                let along = u * dx + v * dy;
                let across = -u * dy + v * dx;
                if along.abs() < half_len && across.abs() < half_w {
                    for c in 0..self.channels {
                        img[(y * s + x) * self.channels + c] = 0.95;
                    }
                }
            }
        }
    }

    fn add_noise(&self, img: &mut [f32], rng: &mut Rng, amp: f32) {
        for px in img.iter_mut() {
            *px = (*px + amp * rng.normal_f32(0.0, 1.0)).clamp(-1.0, 1.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};

    #[test]
    fn nineteen_tasks_three_groups() {
        assert_eq!(SYNTH_VTAB.len(), 19);
        let nat = SYNTH_VTAB.iter().filter(|t| t.group == Group::Natural).count();
        let spec = SYNTH_VTAB.iter().filter(|t| t.group == Group::Specialized).count();
        let strct = SYNTH_VTAB.iter().filter(|t| t.group == Group::Structured).count();
        assert_eq!((nat, spec, strct), (7, 4, 8));
        // class counts fit the 32-way head
        assert!(SYNTH_VTAB.iter().all(|t| t.classes <= 32 && t.classes >= 2));
    }

    #[test]
    fn lookup_by_either_name() {
        assert_eq!(task_by_name("syn-dtd").unwrap().vtab_analog, "DTD");
        assert_eq!(task_by_name("dtd").unwrap().name, "syn-dtd");
        assert!(task_by_name("nope").is_err());
    }

    #[test]
    fn generation_is_deterministic() {
        let spec = task_by_name("syn-caltech101").unwrap();
        let (a, _) = generate_task(spec, 16, 32, 8, 7).unwrap();
        let (b, _) = generate_task(spec, 16, 32, 8, 7).unwrap();
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn different_seeds_differ() {
        let spec = task_by_name("syn-caltech101").unwrap();
        let (a, _) = generate_task(spec, 16, 32, 8, 7).unwrap();
        let (b, _) = generate_task(spec, 16, 32, 8, 8).unwrap();
        assert_ne!(a.images, b.images);
    }

    #[test]
    fn labels_balanced_and_in_range() {
        for spec in SYNTH_VTAB {
            let (train, _) = generate_task(spec, 16, spec.classes * 4, 0, 1).unwrap();
            let mut counts = vec![0usize; spec.classes];
            for &l in &train.labels {
                assert!((l as usize) < spec.classes, "{} label {l}", spec.name);
                counts[l as usize] += 1;
            }
            assert!(counts.iter().all(|&c| c == 4), "{}: {counts:?}", spec.name);
        }
    }

    #[test]
    fn pixels_bounded() {
        check(
            "pixel-range",
            8,
            |r| SYNTH_VTAB[r.below(SYNTH_VTAB.len())].clone(),
            |spec| {
                let (train, _) = generate_task(spec, 16, 16, 0, 3)
                    .map_err(|e| e.to_string())?;
                ensure(
                    train.images.iter().all(|&v| (-1.01..=1.01).contains(&v)),
                    format!("{} pixels out of range", spec.name),
                )
            },
        );
    }

    #[test]
    fn batch_assembly_and_wraparound() {
        let spec = task_by_name("syn-pets").unwrap();
        let (train, _) = generate_task(spec, 16, 10, 0, 1).unwrap();
        let (imgs, labs) = train.batch(&[0, 9, 10]).unwrap(); // 10 wraps to 0
        assert_eq!(imgs.shape, vec![3, 16, 16, 3]);
        assert_eq!(labs.i32s().unwrap()[2], labs.i32s().unwrap()[0]);
    }

    #[test]
    fn upstream_corpus_shapes() {
        let c = upstream_corpus(16, 32, 64, 1).unwrap();
        assert_eq!(c.classes, 32);
        assert_eq!(c.images.len(), 64 * 16 * 16 * 3);
    }

    #[test]
    fn prototype_classes_are_separable() {
        // Same-class pairs must be closer on average than cross-class pairs
        // (sanity: the task is learnable).
        let spec = task_by_name("syn-flowers102").unwrap();
        let (train, _) = generate_task(spec, 16, spec.classes * 6, 0, 11).unwrap();
        let isz = train.image_numel();
        let img = |i: usize| &train.images[i * isz..(i + 1) * isz];
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum()
        };
        let mut same = Vec::new();
        let mut cross = Vec::new();
        for i in 0..train.n {
            for j in (i + 1)..train.n {
                let d = dist(img(i), img(j));
                if train.labels[i] == train.labels[j] {
                    same.push(d);
                } else {
                    cross.push(d);
                }
            }
        }
        let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
        assert!(
            mean(&same) < mean(&cross),
            "same {} !< cross {}",
            mean(&same),
            mean(&cross)
        );
    }
}
