//! Epoch-shuffled minibatch index iterator (fixed batch size: AOT graphs
//! have static shapes, so tail batches wrap around the shuffled order).

use crate::util::rng::Rng;

#[derive(Debug)]
pub struct Batcher {
    n: usize,
    batch: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    pub epoch: usize,
}

impl Batcher {
    pub fn new(n: usize, batch: usize, seed: u64) -> Batcher {
        assert!(n > 0 && batch > 0);
        let mut rng = Rng::new(seed);
        let order = rng.permutation(n);
        Batcher { n, batch, order, cursor: 0, rng, epoch: 0 }
    }

    /// Number of batches that cover the dataset once (ceil).
    pub fn batches_per_epoch(&self) -> usize {
        self.n.div_ceil(self.batch)
    }

    /// Next minibatch of indices; reshuffles at epoch boundaries. The tail
    /// batch wraps into the next epoch's order so every batch is full-size.
    pub fn next_batch(&mut self) -> Vec<usize> {
        let mut ids = Vec::with_capacity(self.batch);
        while ids.len() < self.batch {
            if self.cursor == self.order.len() {
                self.rng.shuffle(&mut self.order);
                self.cursor = 0;
                self.epoch += 1;
            }
            ids.push(self.order[self.cursor]);
            self.cursor += 1;
        }
        ids
    }

    /// Sequential (unshuffled) batches covering 0..n exactly once, with the
    /// final batch padded by cycling its own valid items — for evaluation.
    /// Returns (ids, valid) where `valid` is the count of non-padding
    /// entries; padded ids are always in `0..n`.
    pub fn eval_batches(n: usize, batch: usize) -> Vec<(Vec<usize>, usize)> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < n {
            let valid = batch.min(n - i);
            let mut ids: Vec<usize> = (i..i + valid).collect();
            while ids.len() < batch {
                // cycle this batch's valid prefix: the old expression
                // (ids.len() - valid + i, no modulo) walked past n whenever
                // batch > 2 * valid
                let pad = ids.len() - valid;
                ids.push(i + pad % valid);
            }
            out.push((ids, valid));
            i += valid;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn covers_dataset_each_epoch() {
        let mut b = Batcher::new(10, 4, 0);
        let mut seen = HashSet::new();
        // 3 batches = 12 draws: one full epoch (10) + 2 of the next
        for _ in 0..3 {
            for i in b.next_batch() {
                seen.insert(i);
            }
        }
        assert_eq!(seen.len(), 10);
        assert_eq!(b.epoch, 1);
    }

    #[test]
    fn batches_always_full() {
        let mut b = Batcher::new(7, 4, 1);
        for _ in 0..10 {
            assert_eq!(b.next_batch().len(), 4);
        }
    }

    #[test]
    fn eval_batches_cover_exactly_once() {
        let batches = Batcher::eval_batches(10, 4);
        assert_eq!(batches.len(), 3);
        let valid_total: usize = batches.iter().map(|(_, v)| v).sum();
        assert_eq!(valid_total, 10);
        let (last_ids, last_valid) = &batches[2];
        assert_eq!(*last_valid, 2);
        assert_eq!(last_ids.len(), 4);
        // valid prefix is the remaining items
        assert_eq!(&last_ids[..2], &[8, 9]);
        // padding cycles the valid prefix and every id stays in-range
        assert_eq!(&last_ids[2..], &[8, 9]);
        for (ids, _) in &batches {
            assert!(ids.iter().all(|&i| i < 10), "padded id out of range: {ids:?}");
        }
    }

    #[test]
    fn eval_padding_stays_in_range_when_tail_is_tiny() {
        // valid=1 tail with batch=4: the old wrap expression produced
        // 4, 5, 6 — indices past the dataset
        let batches = Batcher::eval_batches(5, 4);
        let (last_ids, last_valid) = batches.last().unwrap();
        assert_eq!(*last_valid, 1);
        assert_eq!(last_ids, &vec![4, 4, 4, 4]);
        for (ids, _) in &batches {
            assert!(ids.iter().all(|&i| i < 5), "padded id out of range: {ids:?}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Batcher::new(20, 8, 5);
        let mut b = Batcher::new(20, 8, 5);
        for _ in 0..5 {
            assert_eq!(a.next_batch(), b.next_batch());
        }
    }
}
