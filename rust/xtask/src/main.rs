//! Repo automation: `cargo xtask <command>` (aliased in .cargo/config.toml).
//!
//! `cargo xtask lint` runs two source-level discipline gates over the
//! hot-path modules and exits non-zero on any violation (CI blocks on it):
//!
//! 1. **Panic lint.** `serve/`, `runtime/`, `coordinator/session.rs` and
//!    the round engine (`coordinator/rounds.rs` + `faults.rs`) run on
//!    worker threads where a panic poisons shared mutexes and kills the
//!    executor, so `.unwrap()` / `.expect(` / `panic!` and friends are
//!    denied outside `#[cfg(test)]`. Two escape hatches, both in-repo:
//!    - the *class allowlist*: `.unwrap()` directly on a declared lock
//!      field's `.lock()/.read()/.write()/.wait()/.wait_timeout()` — lock
//!      poisoning means a sibling worker already panicked, and propagating
//!      is the only sound move;
//!    - an inline `// lint:allow(panic): <justification>` comment on the
//!      offending line or the comment block immediately above it.
//!
//! 2. **Lock-order lint.** Guards in serve/runtime must be acquired in the
//!    declared global order (see [`LOCK_ORDER`] and docs/contracts.md);
//!    acquiring a lock while holding one of equal or higher rank is a
//!    deadlock waiting for the right interleaving. Helper functions that
//!    acquire locks internally are modeled via [`HELPER_ACQS`].
//!
//! Both lints scan a *normalized* view of each file — comments, string
//! literals and `#[cfg(test)]` items stripped, whitespace collapsed — so a
//! call chain split across lines (`.write()\n.unwrap()`) is still seen.
//! The scanner is deliberately a character-stream pass, not a full parser:
//! it is conservative, dependency-free, and pinned by the unit tests below.

use std::path::Path;
use std::process::ExitCode;

/// Files covered by the panic lint, relative to `rust/src/`.
const PANIC_FILES: [&str; 11] = [
    "serve/mod.rs",
    "runtime/mod.rs",
    "runtime/manifest.rs",
    "runtime/tensor.rs",
    "coordinator/session.rs",
    "coordinator/rounds.rs",
    "coordinator/faults.rs",
    "net/wire.rs",
    "net/server.rs",
    "net/participant.rs",
    "net/standby.rs",
];

/// Files covered by the lock-order lint. The round engine holds no locks
/// by construction (all state lives in the coordinator loop, workers talk
/// over channels); keeping it in the list means any future lock sneaking
/// in is ordered from day one.
const LOCK_FILES: [&str; 8] = [
    "serve/mod.rs",
    "runtime/mod.rs",
    "coordinator/rounds.rs",
    "coordinator/faults.rs",
    "net/wire.rs",
    "net/server.rs",
    "net/participant.rs",
    "net/standby.rs",
];

/// Denied panic-path constructs.
const DENY: [&str; 6] = [
    ".unwrap()",
    ".expect(",
    "panic!(",
    "unreachable!(",
    "todo!(",
    "unimplemented!(",
];

/// Declared lock/condvar fields whose poisoning-`unwrap()`s are
/// class-allowed (runtime: cache/compile_lock/prepared/prepare_lock plus
/// the residency pair resident/slots; serve: swap, state+ready
/// (scheduler), live, stats; net: peers+joined (registry), pending,
/// uploads, wire (participant write half), ship (standby replication
/// link)).
const LOCK_FIELDS: [&str; 17] = [
    "prepare_lock",
    "compile_lock",
    "cache",
    "prepared",
    "resident",
    "slots",
    "swap",
    "state",
    "ready",
    "live",
    "stats",
    "peers",
    "joined",
    "pending",
    "uploads",
    "wire",
    "ship",
];

/// The global lock acquisition order: a lock may only be acquired while
/// every held lock has a strictly LOWER rank. `ready` is a condvar, not a
/// lock, so it carries no rank. `swap` ranks first because the donation
/// fallback compiles + prepares (most of the runtime stack) under it.
const LOCK_ORDER: [(&str, u32); 15] = [
    ("swap", 1),         // serve: per-task swap serialization
    ("prepare_lock", 2), // runtime: parameter-literal conversion critical section
    ("compile_lock", 3), // runtime: XLA compilation critical section
    ("cache", 4),        // runtime: executable cache (RwLock)
    ("prepared", 5),     // runtime: prepared-literal cache
    ("resident", 6),     // runtime: resident-set LRU registry
    ("slots", 7),        // runtime: per-set frozen slots (RwLock)
    ("state", 8),        // serve: scheduler queues
    ("live", 9),         // serve: per-task live (params, prepared set) pair
    ("stats", 10),       // serve: per-task counters
    ("peers", 11),       // net: participant registry (joined condvar: no rank)
    ("pending", 12),     // net: engine requests awaiting remote replies
    ("uploads", 13),     // net: upload dedupe log
    ("wire", 14),        // net participant: shared write half of the socket
    ("ship", 15),        // net coordinator: standby replication link (leaf)
];

/// Functions that acquire locks internally: calling one while holding a
/// lock of equal/higher rank than anything the helper takes is the same
/// deadlock as acquiring it directly.
const HELPER_ACQS: [(&str, &[&str]); 26] = [
    ("self.executable(", &["compile_lock", "cache"]),
    ("self.donate_swap(", &["live", "slots"]),
    ("self.prepared_lookup(", &["prepared"]),
    (
        "rt.prepare(",
        &["prepare_lock", "compile_lock", "cache", "prepared", "resident", "slots"],
    ),
    (
        "prepare_store(",
        &["prepare_lock", "compile_lock", "cache", "prepared", "resident", "slots"],
    ),
    ("self.make_resident(", &["resident", "slots"]),
    ("self.remake_resident(", &["resident", "slots"]),
    ("self.upload_set(", &["slots"]),
    ("self.install_resident(", &["slots"]),
    ("self.upload_and_install(", &["slots"]),
    ("self.evict_over_budget(", &["resident", "slots"]),
    ("rt.execute_prepared(", &["resident", "slots"]),
    ("rt.donate_writeback(", &["slots"]),
    ("rt.stats(", &["resident"]),
    // net coordinator (NetState helpers; `state.` covers `self.state.` too)
    ("state.fail_pending(", &["pending"]),
    ("self.fail_pending(", &["pending"]),
    ("state.complete(", &["pending"]),
    ("self.complete(", &["pending"]),
    ("state.broadcast(", &["peers"]),
    ("state.handle_upload(", &["uploads", "pending"]),
    ("state.await_attach(", &["peers"]),
    ("state.insert_pending(", &["pending"]),
    // standby replication link (all ship-lock helpers live on NetState)
    ("st.ship_entry(", &["ship"]),
    ("state.attach_standby(", &["ship"]),
    ("state.ship_heartbeat(", &["ship"]),
    ("state.ship_close(", &["ship"]),
];

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => lint(),
        _ => {
            eprintln!("usage: cargo xtask lint");
            eprintln!("  lint   panic-discipline + lock-order gates over the hot paths");
            ExitCode::from(2)
        }
    }
}

fn lint() -> ExitCode {
    // xtask/ lives next to src/ inside rust/
    let src = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask has a parent dir")
        .join("src");
    let mut violations: Vec<String> = Vec::new();
    for rel in PANIC_FILES {
        let raw = match std::fs::read_to_string(src.join(rel)) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("error: cannot read {rel}: {e}");
                return ExitCode::from(2);
            }
        };
        let norm = Norm::of(&raw);
        violations.extend(panic_lint(rel, &raw, &norm));
        if LOCK_FILES.contains(&rel) {
            violations.extend(lock_lint(rel, &norm));
        }
    }
    if violations.is_empty() {
        println!(
            "xtask lint: clean ({} files, panic + lock-order gates)",
            PANIC_FILES.len()
        );
        ExitCode::SUCCESS
    } else {
        for v in &violations {
            eprintln!("{v}");
        }
        eprintln!("xtask lint: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}

// ---------------------------------------------------------------------------
// Normalized source view
// ---------------------------------------------------------------------------

/// A file with comments, string/char literals and `#[cfg(test)]` items
/// removed and whitespace collapsed (a single space survives only between
/// two identifier characters, so `let x` keeps its boundary but a call
/// chain split across lines fuses back together). `line[i]` is the
/// 1-based source line of `text` byte `i`.
struct Norm {
    text: String,
    line: Vec<u32>,
}

fn is_ident(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

impl Norm {
    fn of(src: &str) -> Norm {
        let (bytes, lines) = strip_comments_and_literals(src);
        let (bytes, lines) = strip_cfg_test(&bytes, &lines);
        collapse_whitespace(&bytes, &lines)
    }
}

/// Pass 1: drop comments and string/char literal *contents*, preserve all
/// code bytes and line structure. Non-ASCII (only legal inside the removed
/// regions or identifiers we never match on) becomes `_`.
fn strip_comments_and_literals(src: &str) -> (Vec<u8>, Vec<u32>) {
    let b = src.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let mut lines = Vec::with_capacity(b.len());
    let mut line: u32 = 1;
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                out.push(b' ');
                lines.push(line);
                line += 1;
                i += 1;
            }
            b'/' if b.get(i + 1) == Some(&b'/') => {
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
            }
            b'/' if b.get(i + 1) == Some(&b'*') => {
                // block comments nest in Rust
                let mut depth = 1;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
            }
            b'"' => {
                i += 1;
                while i < b.len() {
                    match b[i] {
                        // count the newline a `\`-continuation escapes
                        b'\\' => {
                            if b.get(i + 1) == Some(&b'\n') {
                                line += 1;
                            }
                            i += 2;
                        }
                        b'\n' => {
                            line += 1;
                            i += 1;
                        }
                        b'"' => {
                            i += 1;
                            break;
                        }
                        _ => i += 1,
                    }
                }
            }
            b'r' | b'b' if !out.last().copied().is_some_and(is_ident) => {
                // raw strings only: r"..", r#".."#, br#".."#. A plain
                // b".." byte string falls through so the '"' arm handles
                // its backslash escapes.
                let mut j = i + 1;
                let saw_r = c == b'r' || (c == b'b' && b.get(j) == Some(&b'r'));
                if c == b'b' && b.get(j) == Some(&b'r') {
                    j += 1;
                }
                let mut hashes = 0;
                while b.get(j) == Some(&b'#') {
                    hashes += 1;
                    j += 1;
                }
                if saw_r && b.get(j) == Some(&b'"') {
                    // scan for closing quote + matching hashes
                    j += 1;
                    'scan: while j < b.len() {
                        if b[j] == b'\n' {
                            line += 1;
                        } else if b[j] == b'"' {
                            let mut k = 0;
                            while k < hashes && b.get(j + 1 + k) == Some(&b'#') {
                                k += 1;
                            }
                            if k == hashes {
                                j += 1 + hashes;
                                break 'scan;
                            }
                        }
                        j += 1;
                    }
                    i = j;
                } else {
                    out.push(c);
                    lines.push(line);
                    i += 1;
                }
            }
            b'\'' => {
                // char literal vs lifetime
                if b.get(i + 1) == Some(&b'\\') {
                    i += 3; // '\x — skip escape lead-in
                    while i < b.len() && b[i] != b'\'' {
                        i += 1;
                    }
                    i += 1;
                } else if b.get(i + 2) == Some(&b'\'') && b.get(i + 1) != Some(&b'\'') {
                    i += 3; // 'x'
                } else {
                    i += 1; // lifetime tick: drop it, keep the ident
                }
            }
            _ if c.is_ascii_whitespace() => {
                out.push(b' ');
                lines.push(line);
                i += 1;
            }
            _ if c.is_ascii() => {
                out.push(c);
                lines.push(line);
                i += 1;
            }
            _ => {
                out.push(b'_');
                lines.push(line);
                i += 1;
            }
        }
    }
    (out, lines)
}

/// Pass 2: remove every `#[cfg(test)]` item — the attribute, any further
/// attributes, and the following item through its closing `}` (or `;`).
fn strip_cfg_test(b: &[u8], lines: &[u32]) -> (Vec<u8>, Vec<u32>) {
    const ATTR: &[u8] = b"#[cfg(test)]";
    let mut keep = vec![true; b.len()];
    let mut i = 0;
    while i + ATTR.len() <= b.len() {
        if &b[i..i + ATTR.len()] != ATTR {
            i += 1;
            continue;
        }
        let start = i;
        let mut j = i + ATTR.len();
        loop {
            while j < b.len() && b[j].is_ascii_whitespace() {
                j += 1;
            }
            if b.get(j) == Some(&b'#') {
                // another attribute: skip its [...] bracket group
                j += 1;
                let mut depth = 0;
                while j < b.len() {
                    match b[j] {
                        b'[' => depth += 1,
                        b']' => {
                            depth -= 1;
                            if depth == 0 {
                                j += 1;
                                break;
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
            } else {
                break;
            }
        }
        // the item itself: through the first `;`, or brace-matched `{...}`
        while j < b.len() && b[j] != b'{' && b[j] != b';' {
            j += 1;
        }
        if b.get(j) == Some(&b'{') {
            let mut depth = 0;
            while j < b.len() {
                match b[j] {
                    b'{' => depth += 1,
                    b'}' => {
                        depth -= 1;
                        if depth == 0 {
                            j += 1;
                            break;
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
        } else if b.get(j) == Some(&b';') {
            j += 1;
        }
        for k in keep.iter_mut().take(j.min(b.len())).skip(start) {
            *k = false;
        }
        i = j.max(i + 1);
    }
    let mut ob = Vec::with_capacity(b.len());
    let mut ol = Vec::with_capacity(b.len());
    for (k, (&byte, &ln)) in keep.iter().zip(b.iter().zip(lines.iter())) {
        if *k {
            ob.push(byte);
            ol.push(ln);
        }
    }
    (ob, ol)
}

/// Pass 3: collapse whitespace — keep one space only between two identifier
/// bytes, drop it everywhere else.
fn collapse_whitespace(b: &[u8], lines: &[u32]) -> Norm {
    let mut text = Vec::with_capacity(b.len());
    let mut line = Vec::with_capacity(b.len());
    let mut i = 0;
    while i < b.len() {
        if b[i].is_ascii_whitespace() {
            let ws_line = lines[i];
            let mut j = i;
            while j < b.len() && b[j].is_ascii_whitespace() {
                j += 1;
            }
            let prev_ident = text.last().copied().is_some_and(is_ident);
            let next_ident = b.get(j).copied().is_some_and(is_ident);
            if prev_ident && next_ident {
                text.push(b' ');
                line.push(ws_line);
            }
            i = j;
        } else {
            text.push(b[i]);
            line.push(lines[i]);
            i += 1;
        }
    }
    Norm {
        text: String::from_utf8(text).expect("normalized stream is ASCII"),
        line,
    }
}

// ---------------------------------------------------------------------------
// Panic lint
// ---------------------------------------------------------------------------

/// Lines (1-based) on which a panic site is covered by an inline
/// `lint:allow(panic)` directive: the directive's own line, plus every code
/// line reachable from a directive by walking down through the comment
/// block that carries it.
fn allowed_lines(raw: &str) -> Vec<bool> {
    let lines: Vec<&str> = raw.lines().collect();
    let mut allowed = vec![false; lines.len() + 2];
    for (idx, l) in lines.iter().enumerate() {
        if !l.contains("lint:allow(panic)") {
            continue;
        }
        allowed[idx + 1] = true;
        // cover the first code line below the directive's comment block
        let mut j = idx + 1;
        while j < lines.len() {
            let t = lines[j].trim();
            allowed[j + 1] = true;
            if !(t.is_empty() || t.starts_with("//")) {
                break;
            }
            j += 1;
        }
    }
    allowed
}

/// True when `.unwrap()` at the end of `pre` is the class-allowed
/// lock-poisoning form: `<field>.lock()/.read()/.write()` or
/// `<field>.wait(..)/.wait_timeout(..)` on a declared lock/condvar field.
fn class_allowed(pre: &str) -> bool {
    for m in [".lock()", ".read()", ".write()"] {
        if let Some(stripped) = pre.strip_suffix(m) {
            return LOCK_FIELDS.contains(&ident_suffix(stripped));
        }
    }
    if pre.ends_with(')') {
        // scan back over the call's parens to find the method name
        let bytes = pre.as_bytes();
        let mut depth = 0i32;
        let mut i = bytes.len();
        while i > 0 {
            i -= 1;
            match bytes[i] {
                b')' => depth += 1,
                b'(' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
        }
        let head = &pre[..i];
        for m in [".wait", ".wait_timeout"] {
            if let Some(stripped) = head.strip_suffix(m) {
                return LOCK_FIELDS.contains(&ident_suffix(stripped));
            }
        }
    }
    false
}

/// The trailing identifier of `s` (empty if none).
fn ident_suffix(s: &str) -> &str {
    let b = s.as_bytes();
    let mut i = b.len();
    while i > 0 && is_ident(b[i - 1]) {
        i -= 1;
    }
    &s[i..]
}

fn panic_lint(label: &str, raw: &str, norm: &Norm) -> Vec<String> {
    let allowed = allowed_lines(raw);
    let mut out = Vec::new();
    for pat in DENY {
        for (pos, _) in norm.text.match_indices(pat) {
            let line = norm.line[pos] as usize;
            if allowed.get(line).copied().unwrap_or(false) {
                continue;
            }
            if pat == ".unwrap()" && class_allowed(&norm.text[..pos]) {
                continue;
            }
            out.push(format!(
                "{label}:{line}: denied `{pat}` in a hot-path module — return a \
                 Result, use the lock-poisoning class allowlist, or add \
                 `// lint:allow(panic): <justification>`"
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Lock-order lint
// ---------------------------------------------------------------------------

fn rank_of(name: &str) -> Option<u32> {
    LOCK_ORDER.iter().find(|(n, _)| *n == name).map(|(_, r)| *r)
}

#[derive(Debug)]
struct Held {
    name: &'static str,
    rank: u32,
    depth: u32,
    line: usize,
}

fn lock_lint(label: &str, norm: &Norm) -> Vec<String> {
    let mut out = Vec::new();
    let mut held: Vec<Held> = Vec::new();
    let mut depth: u32 = 0;
    let mut start = 0usize;
    let bytes = norm.text.as_bytes();
    for i in 0..=bytes.len() {
        let term = if i == bytes.len() { b';' } else { bytes[i] };
        if term != b'{' && term != b'}' && term != b';' && i < bytes.len() {
            continue;
        }
        check_stmt(label, norm, start, i, depth, term, &mut held, &mut out);
        match term {
            b'{' => depth += 1,
            b'}' => {
                depth = depth.saturating_sub(1);
                held.retain(|g| g.depth <= depth);
            }
            _ => {}
        }
        start = i + 1;
    }
    out
}

#[allow(clippy::too_many_arguments)]
fn check_stmt(
    label: &str,
    norm: &Norm,
    start: usize,
    end: usize,
    depth: u32,
    term: u8,
    held: &mut Vec<Held>,
    out: &mut Vec<String>,
) {
    let stmt = &norm.text[start..end];
    // direct acquisitions: `.{field}.lock()/.read()/.write()`
    for (name, rank) in LOCK_ORDER {
        for method in [".lock()", ".read()", ".write()"] {
            let pat = format!(".{name}{method}");
            let Some(pos) = stmt.find(&pat) else { continue };
            let line = norm.line[start + pos] as usize;
            for g in held.iter() {
                if g.rank >= rank {
                    out.push(format!(
                        "{label}:{line}: acquires `{name}` (rank {rank}) while \
                         holding `{}` (rank {}, taken at line {}) — violates \
                         the declared lock order",
                        g.name, g.rank, g.line
                    ));
                }
            }
            // a guard is held past this statement only when bound by `let`
            // with the lock guard itself as the final value; a trailing
            // call (`.clone()`, `.get(..)`) extracts and drops the guard
            let is_guard = stmt.contains("let ")
                && (stmt.ends_with(".unwrap()")
                    || stmt.ends_with(&pat));
            if is_guard {
                // guards bound in an `if let`/`while` header live in the
                // body scope (term == '{'), plain `let`s in the current one
                let gdepth = if term == b'{' { depth + 1 } else { depth };
                held.push(Held { name, rank, depth: gdepth, line });
            }
        }
    }
    // indirect acquisitions through helpers
    for (pat, locks) in HELPER_ACQS {
        for (pos, _) in stmt.match_indices(pat) {
            // skip the helper's own definition and partial-ident matches
            if stmt[..pos].ends_with("fn ")
                || stmt[..pos].as_bytes().last().copied().is_some_and(is_ident)
            {
                continue;
            }
            let line = norm.line[start + pos] as usize;
            for lname in locks.iter() {
                let rank = rank_of(lname).expect("helper table names ranked locks");
                for g in held.iter() {
                    if g.rank >= rank {
                        out.push(format!(
                            "{label}:{line}: calls `{}` which acquires `{lname}` \
                             (rank {rank}) while holding `{}` (rank {}, taken at \
                             line {}) — violates the declared lock order",
                            pat.trim_end_matches('('),
                            g.name,
                            g.rank,
                            g.line
                        ));
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strip_removes_comments_strings_and_test_items() {
        let src = r#"
fn a() {
    // x.unwrap() in a comment
    let s = "y.unwrap() in a string";
    real();
}
#[cfg(test)]
mod tests {
    fn t() { x.unwrap(); }
}
fn b() {}
"#;
        let n = Norm::of(src);
        assert!(!n.text.contains(".unwrap()"), "{}", n.text);
        assert!(n.text.contains("real();"));
        assert!(n.text.contains("fn b()"));
        assert!(!n.text.contains("mod tests"));
    }

    #[test]
    fn multiline_chain_fuses_and_keeps_line_map() {
        let src = "fn a() {\n    self.cache\n        .write()\n        .unwrap()\n        .insert(k, v);\n}\n";
        let n = Norm::of(src);
        assert!(n.text.contains("self.cache.write().unwrap().insert(k,v);"));
        let pos = n.text.find(".unwrap()").unwrap();
        assert_eq!(n.line[pos], 4, "the unwrap maps to its source line");
        // and the class allowlist accepts it: cache is a declared lock
        let raw_lint = panic_lint("f", src, &n);
        assert!(raw_lint.is_empty(), "{raw_lint:?}");
    }

    #[test]
    fn bare_unwrap_is_flagged_with_line() {
        let src = "fn a() {\n    let v = maybe().unwrap();\n}\n";
        let n = Norm::of(src);
        let vs = panic_lint("f", src, &n);
        assert_eq!(vs.len(), 1);
        assert!(vs[0].starts_with("f:2:"), "{}", vs[0]);
    }

    #[test]
    fn condvar_wait_unwrap_is_class_allowed() {
        let src = "fn a() {\n    let st = self.ready.wait_timeout(st, d).unwrap().0;\n    let st2 = self.ready.wait(st).unwrap();\n}\n";
        let n = Norm::of(src);
        assert!(panic_lint("f", src, &n).is_empty());
    }

    #[test]
    fn inline_allow_covers_the_next_code_line() {
        let src = "fn a() {\n    x\n        // lint:allow(panic): invariant held\n        // by construction\n        .expect(\"broken\");\n    y.expect(\"not allowed\");\n}\n";
        let n = Norm::of(src);
        let vs = panic_lint("f", src, &n);
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert!(vs[0].starts_with("f:6:"), "{}", vs[0]);
    }

    #[test]
    fn lock_order_violation_is_flagged() {
        // stats (rank 10) held, then state (rank 8) acquired: inverted
        let src = "fn a(&self) {\n    let s = self.stats.lock().unwrap();\n    let q = self.state.lock().unwrap();\n}\n";
        let n = Norm::of(src);
        let vs = lock_lint("f", &n);
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert!(vs[0].contains("acquires `state`"), "{}", vs[0]);
        assert!(vs[0].starts_with("f:3:"), "{}", vs[0]);
    }

    #[test]
    fn declared_order_passes_and_guard_drops_at_scope_end() {
        let src = "fn a(&self) {\n    { let g = self.compile_lock.lock().unwrap();\n      let c = self.cache.read().unwrap(); }\n    let s = self.state.lock().unwrap();\n    drop(s);\n}\nfn b(&self) {\n    let g = self.prepare_lock.lock().unwrap();\n    let p = self.executable(n);\n}\n";
        let n = Norm::of(src);
        let vs = lock_lint("f", &n);
        assert!(vs.is_empty(), "{vs:?}");
    }

    #[test]
    fn helper_call_while_holding_higher_rank_is_flagged() {
        // prepared (rank 5) held, helper acquires compile_lock (rank 3)
        let src = "fn a(&self) {\n    let p = self.prepared.lock().unwrap();\n    let e = self.executable(n);\n}\n";
        let n = Norm::of(src);
        let vs = lock_lint("f", &n);
        assert!(!vs.is_empty(), "expected a helper-order violation");
        assert!(vs[0].contains("self.executable"), "{}", vs[0]);
    }

    #[test]
    fn temporary_extraction_is_not_a_held_guard() {
        // `.read().unwrap().clone()` drops the guard at statement end, so
        // the later (lower-rank) acquisition is legal
        let src = "fn a(&self) {\n    let live = ts.live.read().unwrap().clone();\n    let st = self.state.lock().unwrap();\n}\n";
        let n = Norm::of(src);
        let vs = lock_lint("f", &n);
        assert!(vs.is_empty(), "{vs:?}");
    }

    #[test]
    fn the_real_hot_paths_pass_both_lints() {
        // the same invocation CI runs, as a unit test: the shipped sources
        // must be clean
        let src = Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .unwrap()
            .join("src");
        for rel in PANIC_FILES {
            let raw = std::fs::read_to_string(src.join(rel)).unwrap();
            let n = Norm::of(&raw);
            let vs = panic_lint(rel, &raw, &n);
            assert!(vs.is_empty(), "panic lint: {vs:#?}");
            if LOCK_FILES.contains(&rel) {
                let vs = lock_lint(rel, &n);
                assert!(vs.is_empty(), "lock lint: {vs:#?}");
            }
        }
    }
}
