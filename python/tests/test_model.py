"""L2 model correctness: shapes, calibration stats, sparse-training semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import train as T
from compile.kernels import ref

CFG = M.CONFIGS["micro"]
KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, KEY)


@pytest.fixture(scope="module")
def batch():
    images = jax.random.normal(jax.random.PRNGKey(1),
                               (8, CFG.image_size, CFG.image_size, 3))
    labels = (jnp.arange(8) * 3) % CFG.num_classes
    return images, labels


def test_param_specs_cover_all_params(params):
    assert set(params.keys()) == {s.name for s in M.param_specs(CFG)}
    assert M.num_params(CFG) == sum(int(np.prod(v.shape))
                                    for v in params.values())


def test_masked_specs_are_2d():
    for s in M.masked_specs(CFG):
        assert len(s.shape) == 2
        assert s.stat is not None


def test_forward_shape(params, batch):
    images, _ = batch
    logits = M.forward(CFG, params, images)
    assert logits.shape == (8, CFG.num_classes)
    assert bool(jnp.isfinite(logits).all())


def test_patchify_roundtrip():
    """patchify must preserve pixel values (just a relayout)."""
    images = jax.random.normal(jax.random.PRNGKey(2),
                               (2, CFG.image_size, CFG.image_size, 3))
    p = M.patchify(CFG, images)
    assert p.shape == (2, CFG.n_patches, CFG.patch_dim)
    # patch (0,0) of image 0 == first patch row
    blk = images[0, :CFG.patch_size, :CFG.patch_size, :].reshape(-1)
    np.testing.assert_allclose(p[0, 0], blk, rtol=1e-6)


def test_stats_match_manual_patch_embed(params, batch):
    """The calibration stat for patch_embed.w must equal the column-norm²
    of the patchified input — verifies stat wiring end to end."""
    images, _ = batch
    _, stats = M.forward(CFG, params, images, collect_stats=True)
    patches = M.patchify(CFG, images).reshape(-1, CFG.patch_dim)
    want = ref.activation_colnorm_sq(patches)
    np.testing.assert_allclose(stats["patch_embed.in"], want,
                               rtol=1e-4, atol=1e-4)


def test_stats_complete_and_finite(params, batch):
    images, _ = batch
    _, stats = M.forward(CFG, params, images, collect_stats=True)
    for s in M.masked_specs(CFG):
        assert s.stat in stats
        assert stats[s.stat].shape == (s.shape[0],)
        assert bool(jnp.isfinite(stats[s.stat]).all())
        assert bool((stats[s.stat] >= 0).all())


def test_forward_with_stats_matches_plain(params, batch):
    images, _ = batch
    logits = M.forward(CFG, params, images)
    logits2, _ = M.forward(CFG, params, images, collect_stats=True)
    np.testing.assert_allclose(logits, logits2, rtol=1e-5, atol=1e-5)


def test_train_step_only_updates_masked(params, batch):
    images, labels = batch
    # mask: qkv of block0 only
    masks = {k: jnp.zeros_like(v) for k, v in params.items()}
    masks["block0.attn.qkv.w"] = jnp.ones_like(params["block0.attn.qkv.w"])
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(x) for k, x in params.items()}
    new_p, new_m, new_v, loss, nc, t5 = T.train_step_adam(
        CFG, params, masks, m, v, 1.0, images, labels, 1e-3, 0.0)
    for name in params:
        if name == "block0.attn.qkv.w":
            assert not np.allclose(new_p[name], params[name])
        else:
            np.testing.assert_array_equal(new_p[name], params[name])
            assert (np.asarray(new_m[name]) == 0).all()


def test_train_loss_decreases_overfitting_one_batch(params, batch):
    """Full-mask Adam on one batch must overfit rapidly (sanity of the
    whole fwd/bwd/update composition)."""
    images, labels = batch
    masks = {k: jnp.ones_like(v) for k, v in params.items()}
    p = params
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(x) for k, x in params.items()}
    losses = []
    for step in range(1, 9):
        p, m, v, loss, nc, _ = T.train_step_adam(
            CFG, p, masks, m, v, float(step), images, labels, 5e-3, 0.0)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, losses


def test_eval_step_counts(params, batch):
    images, labels = batch
    loss_sum, nc, t5 = T.eval_step(CFG, params, images, labels)
    assert 0 <= float(nc) <= 8
    assert float(nc) <= float(t5) <= 8
    assert float(loss_sum) > 0


def test_lora_delta_zero_at_init(params, batch):
    """LoRA B is zero-initialized -> first forward equals the backbone."""
    images, labels = batch
    lb, la = T.init_lora(CFG, KEY)
    masks = {k: jnp.ones(params[k].shape, jnp.float32) for k in lb}
    loss_l, nc_l, _ = T.lora_eval_step(CFG, params, lb, la, masks, images,
                                       labels)
    loss_d, nc_d, _ = T.eval_step(CFG, params, images, labels)
    np.testing.assert_allclose(float(loss_l), float(loss_d), rtol=1e-4)
    assert float(nc_l) == float(nc_d)


def test_lora_train_moves_only_adapters(params, batch):
    images, labels = batch
    lb, la = T.init_lora(CFG, KEY)
    masks = {k: jnp.ones(params[k].shape, jnp.float32) for k in lb}
    zb = {k: jnp.zeros_like(x) for k, x in lb.items()}
    za = {k: jnp.zeros_like(x) for k, x in la.items()}
    nb, na, *_ , loss, nc, t5 = T.lora_train_step(
        CFG, params, lb, la, masks, zb, dict(zb), za, dict(za), 1.0,
        images, labels, 1e-2, 0.0)
    moved = sum(not np.allclose(nb[k], lb[k]) for k in lb)
    assert moved > 0  # B gets gradient through (B·A)⊙M even at B=0


def test_sparse_lora_respects_mask(params, batch):
    """With a sparse mask, the *effective* ΔW stays zero off-mask after
    training steps (Eq. 6)."""
    images, labels = batch
    lb, la = T.init_lora(CFG, KEY)
    name = "block0.attn.qkv.w"
    masks = {k: jnp.ones(params[k].shape, jnp.float32) for k in lb}
    masks[name] = ref.topk_row_mask(
        jnp.abs(jax.random.normal(KEY, params[name].shape)), 4)
    zb = {k: jnp.zeros_like(x) for k, x in lb.items()}
    za = {k: jnp.zeros_like(x) for k, x in la.items()}
    nb, na, *_ = T.lora_train_step(
        CFG, params, lb, la, masks, zb, dict(zb), za, dict(za), 1.0,
        images, labels, 1e-2, 0.0)
    delta = ref.masked_lora_delta(nb[name], na[name], masks[name], 2.0)
    off = np.asarray(masks[name]) == 0
    assert (np.asarray(delta)[off] == 0).all()


def test_vpt_step_runs_and_freezes_backbone(params, batch):
    images, labels = batch
    prompt = T.init_vpt(CFG, KEY)
    hw, hb = params["head.w"], params["head.b"]
    zeros = tuple(jnp.zeros_like(t) for t in (prompt, hw, hb))
    (ntr, nm, nv, loss, nc, t5) = T.vpt_train_step(
        CFG, params, prompt, hw, hb, zeros, zeros, 1.0, images, labels,
        1e-2, 0.0)
    assert not np.allclose(ntr[0], prompt)  # prompt moved
    assert bool(jnp.isfinite(loss))


def test_adapter_zero_init_is_identity(params, batch):
    """Adapter up-projection zero-init: initial forward == backbone."""
    images, labels = batch
    ad = T.init_adapters(CFG, KEY)
    loss_a, nc_a, _ = T.adapter_eval_step(
        CFG, params, ad, params["head.w"], params["head.b"], images, labels)
    loss_d, nc_d, _ = T.eval_step(CFG, params, images, labels)
    np.testing.assert_allclose(float(loss_a), float(loss_d), rtol=1e-4)


def test_grad_scores_shapes(params, batch):
    images, labels = batch
    gs = T.grad_scores_step(CFG, params, images, labels)
    mspecs = M.masked_specs(CFG)
    assert len(gs) == len(mspecs)
    for g, s in zip(gs, mspecs):
        assert g.shape == s.shape
        assert bool((g >= 0).all())


def test_topk_correct_bounds(params, batch):
    images, labels = batch
    logits = M.forward(CFG, params, images)
    t1 = M.n_correct(logits, labels)
    t5 = M.topk_correct(logits, labels, 5)
    tall = M.topk_correct(logits, labels, CFG.num_classes)
    assert float(t1) <= float(t5) <= float(tall) == 8.0
