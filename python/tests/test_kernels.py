"""Kernel-vs-oracle correctness: the CORE L1 signal.

Every Pallas kernel is asserted allclose against its pure-jnp ref under
hypothesis-driven shape sweeps (odd sizes, non-lane-aligned dims, degenerate
k) — interpret mode must agree with the oracle bit-for-bit in selection
semantics and to float tolerance in arithmetic.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref

SETTINGS = dict(max_examples=12, deadline=None)


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# activation_colnorm_sq
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(t=st.integers(1, 200), f=st.integers(1, 160), seed=st.integers(0, 99))
def test_colnorm_matches_ref(t, f, seed):
    x = rand(seed, (t, f))
    got = K.activation_colnorm_sq(x)
    np.testing.assert_allclose(got, ref.activation_colnorm_sq(x),
                               rtol=1e-5, atol=1e-5)


def test_colnorm_zero_input():
    x = jnp.zeros((7, 13))
    np.testing.assert_array_equal(K.activation_colnorm_sq(x), jnp.zeros(13))


def test_colnorm_accumulates_over_batches():
    """Splitting tokens across calls and summing must equal one call —
    the contract the Rust coordinator relies on during calibration."""
    x = rand(0, (64, 24))
    whole = K.activation_colnorm_sq(x)
    parts = K.activation_colnorm_sq(x[:20]) + K.activation_colnorm_sq(x[20:])
    np.testing.assert_allclose(whole, parts, rtol=1e-5)


# ---------------------------------------------------------------------------
# importance_score (Eq. 2)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(do=st.integers(1, 96), di=st.integers(1, 160), seed=st.integers(0, 99))
def test_importance_matches_ref(do, di, seed):
    w = rand(seed, (do, di))
    cn = jnp.abs(rand(seed + 1, (di,)))
    got = K.importance_score(w, cn)
    np.testing.assert_allclose(got, ref.importance_score(w, cn),
                               rtol=1e-5, atol=1e-6)


def test_importance_shape_mismatch_raises():
    with pytest.raises(ValueError):
        K.importance_score(jnp.ones((4, 8)), jnp.ones(9))


def test_importance_is_nonnegative():
    w = rand(3, (16, 32))
    cn = jnp.abs(rand(4, (32,)))
    assert (K.importance_score(w, cn) >= 0).all()


# ---------------------------------------------------------------------------
# topk_row_mask (Alg. 1 step 3)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(do=st.integers(1, 64), di=st.integers(2, 128),
       k=st.integers(1, 128), seed=st.integers(0, 99))
def test_topk_matches_ref(do, di, k, seed):
    s = jnp.abs(rand(seed, (do, di)))
    got = K.topk_row_mask(s, k)
    np.testing.assert_array_equal(got, ref.topk_row_mask(s, k))


@settings(**SETTINGS)
@given(do=st.integers(1, 32), di=st.integers(2, 96),
       k=st.integers(1, 96), seed=st.integers(0, 99))
def test_topk_exact_budget_per_row(do, di, k, seed):
    s = jnp.abs(rand(seed, (do, di)))
    mask = K.topk_row_mask(s, k)
    counts = np.asarray(mask.sum(axis=-1))
    np.testing.assert_array_equal(counts, np.full(do, min(k, di)))


def test_topk_selects_largest():
    s = jnp.array([[1.0, 5.0, 3.0, 4.0, 2.0]])
    mask = K.topk_row_mask(s, 2)
    np.testing.assert_array_equal(mask, [[0, 1, 0, 1, 0]])


def test_topk_tie_break_lowest_index():
    s = jnp.ones((2, 6))
    mask = K.topk_row_mask(s, 3)
    np.testing.assert_array_equal(mask, [[1, 1, 1, 0, 0, 0]] * 2)


def test_topk_k_zero_raises():
    with pytest.raises(ValueError):
        K.topk_row_mask(jnp.ones((2, 4)), 0)


# ---------------------------------------------------------------------------
# nm_mask (structured N:M)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(do=st.integers(1, 48), groups=st.integers(1, 16),
       nm=st.sampled_from([(1, 2), (2, 4), (1, 4), (4, 8), (2, 8)]),
       seed=st.integers(0, 99))
def test_nm_matches_ref(do, groups, nm, seed):
    n, m = nm
    s = jnp.abs(rand(seed, (do, groups * m)))
    got = K.nm_mask(s, n, m)
    np.testing.assert_array_equal(got, ref.nm_mask(s, n, m))


@settings(**SETTINGS)
@given(do=st.integers(1, 32), groups=st.integers(1, 12),
       nm=st.sampled_from([(2, 4), (1, 4), (4, 8)]), seed=st.integers(0, 99))
def test_nm_constraint_holds(do, groups, nm, seed):
    """Every window of m consecutive weights has exactly n survivors —
    the invariant sparse tensor cores require."""
    n, m = nm
    s = jnp.abs(rand(seed, (do, groups * m)))
    mask = np.asarray(K.nm_mask(s, n, m)).reshape(do, groups, m)
    np.testing.assert_array_equal(mask.sum(-1), np.full((do, groups), n))


def test_nm_indivisible_raises():
    with pytest.raises(ValueError):
        K.nm_mask(jnp.ones((4, 10)), 2, 4)


# ---------------------------------------------------------------------------
# masked updates (Alg. 1 step 4)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(do=st.integers(1, 64), di=st.integers(1, 160), seed=st.integers(0, 99))
def test_masked_sgd_matches_ref(do, di, seed):
    w, g = rand(seed, (do, di)), rand(seed + 1, (do, di))
    mom = 0.1 * rand(seed + 2, (do, di))
    mask = (rand(seed + 3, (do, di)) > 0).astype(jnp.float32)
    got = K.masked_sgd(w, g, mask, mom, 0.01, 0.9, 0.001)
    want = ref.masked_sgd(w, g, mask, mom, 0.01, 0.9, 0.001)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@settings(**SETTINGS)
@given(do=st.integers(1, 64), di=st.integers(1, 160),
       step=st.integers(1, 1000), seed=st.integers(0, 99))
def test_masked_adam_matches_ref(do, di, step, seed):
    w, g = rand(seed, (do, di)), rand(seed + 1, (do, di))
    m = 0.1 * rand(seed + 2, (do, di))
    v = jnp.abs(0.1 * rand(seed + 3, (do, di)))
    mask = (rand(seed + 4, (do, di)) > 0).astype(jnp.float32)
    got = K.masked_adam(w, g, mask, m, v, 1e-3, 0.9, 0.999, 1e-8, 0.01,
                        float(step))
    want = ref.masked_adam(w, g, mask, m, v, 1e-3, 0.9, 0.999, 1e-8, 0.01,
                           float(step))
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)


def test_masked_update_freezes_unselected():
    """The defining invariant: coordinates with mask=0 NEVER move, and
    their optimizer state stays zero (paper's memory claim)."""
    w, g = rand(0, (16, 32)), rand(1, (16, 32))
    mask = ref.topk_row_mask(jnp.abs(rand(2, (16, 32))), 4)
    m = jnp.zeros_like(w)
    v = jnp.zeros_like(w)
    w1, m1, v1 = K.masked_adam(w, g, mask, m, v, 1e-2, 0.9, 0.999, 1e-8,
                               0.1, 1.0)
    frozen = np.asarray(mask) == 0
    np.testing.assert_array_equal(np.asarray(w1)[frozen],
                                  np.asarray(w)[frozen])
    assert (np.asarray(m1)[frozen] == 0).all()
    assert (np.asarray(v1)[frozen] == 0).all()


def test_masked_sgd_1d_tensor():
    """Bias vectors (1-D) go through the same kernel (BitFit path)."""
    w, g = rand(0, (33,)), rand(1, (33,))
    mask = jnp.ones_like(w)
    mom = jnp.zeros_like(w)
    w1, _ = K.masked_sgd(w, g, mask, mom, 0.1, 0.0, 0.0)
    np.testing.assert_allclose(w1, w - 0.1 * g, rtol=1e-6)


# ---------------------------------------------------------------------------
# masked_lora_delta (Eq. 6)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(d1=st.integers(1, 64), d2=st.integers(1, 128), r=st.integers(1, 16),
       seed=st.integers(0, 99))
def test_lora_delta_matches_ref(d1, d2, r, seed):
    b = rand(seed, (d1, r))
    a = rand(seed + 1, (r, d2))
    mask = (rand(seed + 2, (d1, d2)) > 0).astype(jnp.float32)
    got = K.masked_lora_delta(b, a, mask, 2.0)
    np.testing.assert_allclose(got, ref.masked_lora_delta(b, a, mask, 2.0),
                               rtol=1e-4, atol=1e-5)


def test_lora_delta_grads_flow_and_respect_mask():
    b = rand(0, (8, 4))
    a = rand(1, (4, 16))
    mask = ref.topk_row_mask(jnp.abs(rand(2, (8, 16))), 4)

    def loss(b, a):
        return jnp.sum(K.masked_lora_delta(b, a, mask, 1.0) ** 2)

    db, da = jax.grad(loss, argnums=(0, 1))(b, a)
    delta_ref = ref.masked_lora_delta(b, a, mask, 1.0)
    db_ref = 2.0 * (delta_ref * mask) @ a.T
    da_ref = 2.0 * b.T @ (delta_ref * mask)
    np.testing.assert_allclose(db, db_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(da, da_ref, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# tiled_matmul
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(m=st.integers(1, 96), k=st.integers(1, 96), n=st.integers(1, 96),
       seed=st.integers(0, 99))
def test_matmul_matches_ref(m, k, n, seed):
    x = rand(seed, (m, k))
    w = rand(seed + 1, (k, n))
    np.testing.assert_allclose(K.tiled_matmul(x, w), ref.matmul(x, w),
                               rtol=1e-4, atol=1e-4)


def test_matmul_grad_matches_jnp():
    x, w = rand(0, (24, 36)), rand(1, (36, 16))

    def f(x, w):
        return jnp.sum(jnp.sin(K.tiled_matmul(x, w)))

    def f_ref(x, w):
        return jnp.sum(jnp.sin(x @ w))

    gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
    rgx, rgw = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx, rgx, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw, rgw, rtol=1e-4, atol=1e-4)


def test_linear_bias_broadcast():
    x = rand(0, (4, 7, 12))
    w = rand(1, (12, 5))
    b = rand(2, (5,))
    got = K.linear(x, w, b)
    np.testing.assert_allclose(got, x @ w + b, rtol=1e-4, atol=1e-4)
