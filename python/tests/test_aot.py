"""AOT pipeline tests: manifest/flat-signature consistency.

These don't run full lowering for every artifact (slow); they verify the
builder signatures agree between `Io` bookkeeping and the constructed
functions, plus one real lowering (micro fwd) produces parseable HLO text
that declares the same number of entry parameters.
"""

import re

import jax
import pytest

from compile import aot
from compile import model as M


CFG = M.CONFIGS["micro"]
BATCH = 4


@pytest.mark.parametrize("kind", list(aot.BUILDERS.keys()))
def test_builder_specs_consistent(kind):
    fn, ins, io = aot.BUILDERS[kind](CFG, BATCH)
    assert len(ins) == len(io.inputs), f"{kind}: spec count mismatch"
    for spec, meta in zip(ins, io.inputs):
        assert tuple(spec.shape) == tuple(meta["shape"]), meta["name"]
    # abstract evaluation must succeed and match declared outputs
    out = jax.eval_shape(fn, *ins)
    flat, _ = jax.tree_util.tree_flatten(out)
    assert len(flat) == len(io.outputs), f"{kind}: output count mismatch"
    for got, meta in zip(flat, io.outputs):
        assert tuple(got.shape) == tuple(meta["shape"]), \
            f"{kind}: {meta['name']} shape {got.shape} != {meta['shape']}"


def test_input_names_unique():
    for kind in aot.BUILDERS:
        _, _, io = aot.BUILDERS[kind](CFG, BATCH)
        names = [i["name"] for i in io.inputs]
        assert len(names) == len(set(names)), f"{kind}: duplicate input names"
        onames = [o["name"] for o in io.outputs]
        assert len(onames) == len(set(onames)), f"{kind}: duplicate outputs"


def test_lowered_hlo_parameter_count_matches_manifest():
    fn, ins, io = aot.build_fwd(CFG, BATCH)
    lowered = jax.jit(fn, keep_unused=True).lower(*ins)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # count parameter declarations in the ENTRY computation
    entry = text[text.index("ENTRY"):]
    params = re.findall(r"parameter\(\d+\)", entry)
    assert len(params) == len(io.inputs)


def test_train_adam_roundtrips_param_layout():
    _, _, io = aot.build_train_adam(CFG, BATCH)
    pnames = [s.name for s in M.param_specs(CFG)]
    in_params = [i["name"][6:] for i in io.inputs if i["name"].startswith("param:")]
    out_params = [o["name"][6:] for o in io.outputs if o["name"].startswith("param:")]
    assert in_params == pnames
    assert out_params == pnames


def test_stat_specs_align_with_masked():
    stats = M.stat_specs(CFG)
    masked = M.masked_specs(CFG)
    assert len(stats) == len(masked)
    for (sname, dim), spec in zip(stats, masked):
        assert sname == spec.stat
        assert dim == spec.shape[0]  # d_in of the (d_in, d_out) layout
