"""L2: the fine-tuning / pre-training step graphs (fwd + bwd + optimizer).

Every function here is AOT-lowered by `aot.py` to one HLO artifact; the Rust
coordinator drives them through PJRT with no Python on the request path.

Uniform sparse-update contract: the train graphs take one mask per parameter
tensor (same shape as the tensor). Alg. 1 step 4 — the masked AdamW/SGD
update — runs through the L1 Pallas kernels, so:

  * TaskEdge / Magnitude / Random / N:M    -> computed masks on 2-D weights
  * Full                                   -> all-ones masks
  * Linear probe                           -> ones on head.* only
  * BitFit                                 -> ones on bias/LN tensors
  * GPS (gradient baseline)                -> masks from the grad_scores graph

LoRA / VPT / Adapter have their own graphs because their trainable state is
not the backbone weights.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model as M
from .kernels import masked_adam, masked_lora_delta, masked_sgd


# ---------------------------------------------------------------------------
# Dense backbone steps (TaskEdge + selective baselines)
# ---------------------------------------------------------------------------

def _loss_and_grads(cfg, params, images, labels, **fwd_kw):
    def loss_fn(p):
        logits = M.forward(cfg, p, images, **fwd_kw)
        return M.cross_entropy(logits, labels), logits

    (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    return loss, logits, grads


def train_step_adam(cfg: M.ViTConfig, params, masks, m, v, step,
                    images, labels, lr, wd):
    """One masked AdamW step.

    params/masks/m/v: dicts keyed by param name (masks for every tensor);
    step: f32 scalar, the 1-based count of this step; returns
    (params', m', v', loss, n_correct, topk_correct)."""
    loss, logits, grads = _loss_and_grads(cfg, params, images, labels)
    new_p, new_m, new_v = {}, {}, {}
    for name in params:
        new_p[name], new_m[name], new_v[name] = masked_adam(
            params[name], grads[name], masks[name], m[name], v[name],
            lr, 0.9, 0.999, 1e-8, wd, step)
    return (new_p, new_m, new_v, loss, M.n_correct(logits, labels),
            M.topk_correct(logits, labels, 5))


def train_step_sgd(cfg: M.ViTConfig, params, masks, moms,
                   images, labels, lr, wd):
    """One masked SGD+momentum step (used for from-scratch pretraining and
    the optimizer ablation). Returns (params', moms', loss, n_correct)."""
    loss, logits, grads = _loss_and_grads(cfg, params, images, labels)
    new_p, new_mom = {}, {}
    for name in params:
        new_p[name], new_mom[name] = masked_sgd(
            params[name], grads[name], masks[name], moms[name], lr, 0.9, wd)
    return new_p, new_mom, loss, M.n_correct(logits, labels)


def eval_step(cfg: M.ViTConfig, params, images, labels):
    """Returns (loss_sum, n_correct, top5_correct) over the batch."""
    logits = M.forward(cfg, params, images)
    loss = M.cross_entropy(logits, labels) * images.shape[0]
    return loss, M.n_correct(logits, labels), M.topk_correct(logits, labels, 5)


def forward_logits(cfg: M.ViTConfig, params, images):
    return M.forward(cfg, params, images)


# ---------------------------------------------------------------------------
# Calibration + scoring inputs (Alg. 1 steps 1-2) and GPS baseline
# ---------------------------------------------------------------------------

def calibrate_step(cfg: M.ViTConfig, params, images):
    """Forward pass that returns the squared activation column norms for the
    input of every masked tensor, in `masked_specs` order."""
    _, stats = M.forward(cfg, params, images, collect_stats=True)
    return tuple(stats[s.stat] for s in M.masked_specs(cfg))


def grad_scores_step(cfg: M.ViTConfig, params, images, labels):
    """|∇W| for every masked tensor (GPS-style baseline scores)."""
    _, _, grads = _loss_and_grads(cfg, params, images, labels)
    return tuple(jnp.abs(grads[s.name]) for s in M.masked_specs(cfg))


# ---------------------------------------------------------------------------
# LoRA / sparse-LoRA (Eq. 6)
# ---------------------------------------------------------------------------

def lora_target_specs(cfg: M.ViTConfig) -> list[M.ParamSpec]:
    """LoRA adapts every masked 2-D weight (paper §III-D applies the mask to
    the generic ΔW = B·A of any weight matrix)."""
    return M.masked_specs(cfg)


def init_lora(cfg: M.ViTConfig, key: jax.Array):
    """B zero-init, A gaussian (standard LoRA init: ΔW = 0 at start)."""
    a, b = {}, {}
    r = cfg.lora_rank
    for spec in lora_target_specs(cfg):
        key, sub = jax.random.split(key)
        d1, d2 = spec.shape
        b[spec.name] = jnp.zeros((d1, r), jnp.float32)
        a[spec.name] = jax.random.normal(sub, (r, d2), jnp.float32) / r
    return b, a


def lora_train_step(cfg: M.ViTConfig, params, lora_b, lora_a, masks,
                    m_b, v_b, m_a, v_a, step, images, labels, lr, wd):
    """Sparse-LoRA AdamW step: backbone frozen, ΔW = (B·A) ⊙ M (Eq. 6).

    masks: per LoRA target, full (d1, d2) shape; all-ones mask == plain LoRA.
    Moments kept for A and B (dense — they are tiny)."""
    scale = 2.0  # alpha / r with alpha = 2r, the common default

    def loss_fn(ba):
        lb, la = ba
        deltas = {name: masked_lora_delta(lb[name], la[name], masks[name], scale)
                  for name in lb}
        logits = M.forward(cfg, params, images, deltas=deltas)
        return M.cross_entropy(logits, labels), logits

    (loss, logits), (gb, ga) = jax.value_and_grad(loss_fn, has_aux=True)(
        (lora_b, lora_a))

    ones_b = {k: jnp.ones_like(v) for k, v in lora_b.items()}
    ones_a = {k: jnp.ones_like(v) for k, v in lora_a.items()}
    nb, nmb, nvb = {}, {}, {}
    na, nma, nva = {}, {}, {}
    for k in lora_b:
        nb[k], nmb[k], nvb[k] = masked_adam(
            lora_b[k], gb[k], ones_b[k], m_b[k], v_b[k],
            lr, 0.9, 0.999, 1e-8, wd, step)
        na[k], nma[k], nva[k] = masked_adam(
            lora_a[k], ga[k], ones_a[k], m_a[k], v_a[k],
            lr, 0.9, 0.999, 1e-8, wd, step)
    return (nb, na, nmb, nvb, nma, nva, loss, M.n_correct(logits, labels),
            M.topk_correct(logits, labels, 5))


def lora_eval_step(cfg: M.ViTConfig, params, lora_b, lora_a, masks,
                   images, labels):
    scale = 2.0
    deltas = {name: masked_lora_delta(lora_b[name], lora_a[name], masks[name],
                                      scale)
              for name in lora_b}
    logits = M.forward(cfg, params, images, deltas=deltas)
    loss = M.cross_entropy(logits, labels) * images.shape[0]
    return loss, M.n_correct(logits, labels), M.topk_correct(logits, labels, 5)


# ---------------------------------------------------------------------------
# VPT baseline (prompt tokens + head)
# ---------------------------------------------------------------------------

def init_vpt(cfg: M.ViTConfig, key: jax.Array) -> jax.Array:
    return 0.02 * jax.random.truncated_normal(
        key, -2.0, 2.0, (cfg.prompt_len, cfg.dim), jnp.float32)


def vpt_train_step(cfg: M.ViTConfig, params, prompt, head_w, head_b,
                   m_state, v_state, step, images, labels, lr, wd):
    """VPT-Shallow: trainable prompt tokens + classification head.

    m_state/v_state: tuples (m_prompt, m_head_w, m_head_b) etc."""

    def loss_fn(tr):
        prm, hw, hb = tr
        p2 = dict(params)
        p2["head.w"], p2["head.b"] = hw, hb
        logits = M.forward(cfg, p2, images, prompt=prm)
        return M.cross_entropy(logits, labels), logits

    (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        (prompt, head_w, head_b))
    tr = (prompt, head_w, head_b)
    new_tr, new_m, new_v = [], [], []
    for t, g, mm, vv in zip(tr, grads, m_state, v_state):
        ones = jnp.ones_like(t)
        nt, nm2, nv2 = masked_adam(t, g, ones, mm, vv,
                                   lr, 0.9, 0.999, 1e-8, wd, step)
        new_tr.append(nt)
        new_m.append(nm2)
        new_v.append(nv2)
    return (tuple(new_tr), tuple(new_m), tuple(new_v), loss,
            M.n_correct(logits, labels), M.topk_correct(logits, labels, 5))


def vpt_eval_step(cfg: M.ViTConfig, params, prompt, head_w, head_b,
                  images, labels):
    p2 = dict(params)
    p2["head.w"], p2["head.b"] = head_w, head_b
    logits = M.forward(cfg, p2, images, prompt=prompt)
    loss = M.cross_entropy(logits, labels) * images.shape[0]
    return loss, M.n_correct(logits, labels), M.topk_correct(logits, labels, 5)


# ---------------------------------------------------------------------------
# Adapter baseline (bottleneck modules + head)
# ---------------------------------------------------------------------------

def adapter_specs(cfg: M.ViTConfig) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for i in range(cfg.depth):
        p = f"block{i}.adapter."
        out += [
            (p + "down.w", (cfg.dim, cfg.adapter_dim)),
            (p + "down.b", (cfg.adapter_dim,)),
            (p + "up.w", (cfg.adapter_dim, cfg.dim)),
            (p + "up.b", (cfg.dim,)),
        ]
    return out


def init_adapters(cfg: M.ViTConfig, key: jax.Array) -> dict[str, jax.Array]:
    out = {}
    for name, shape in adapter_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(".b") or name.endswith("up.w"):
            out[name] = jnp.zeros(shape, jnp.float32)  # zero-init output path
        else:
            out[name] = 0.02 * jax.random.truncated_normal(
                sub, -2.0, 2.0, shape, jnp.float32)
    return out


def adapter_train_step(cfg: M.ViTConfig, params, adapters, head_w, head_b,
                       m_state, v_state, step, images, labels, lr, wd):
    """Houlsby-style adapters (+head). m_state/v_state mirror the trainable
    pytree ((adapters dict), head_w, head_b)."""

    def loss_fn(tr):
        ad, hw, hb = tr
        p2 = dict(params)
        p2["head.w"], p2["head.b"] = hw, hb
        logits = M.forward(cfg, p2, images, adapters=ad)
        return M.cross_entropy(logits, labels), logits

    (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        (adapters, head_w, head_b))

    tr = (adapters, head_w, head_b)
    flat_t, treedef = jax.tree_util.tree_flatten(tr)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(m_state)
    flat_v = jax.tree_util.tree_leaves(v_state)
    new_t, new_m, new_v = [], [], []
    for t, g, mm, vv in zip(flat_t, flat_g, flat_m, flat_v):
        nt, nm2, nv2 = masked_adam(t, g, jnp.ones_like(t), mm, vv,
                                   lr, 0.9, 0.999, 1e-8, wd, step)
        new_t.append(nt)
        new_m.append(nm2)
        new_v.append(nv2)
    return (jax.tree_util.tree_unflatten(treedef, new_t),
            jax.tree_util.tree_unflatten(treedef, new_m),
            jax.tree_util.tree_unflatten(treedef, new_v),
            loss, M.n_correct(logits, labels),
            M.topk_correct(logits, labels, 5))


def adapter_eval_step(cfg: M.ViTConfig, params, adapters, head_w, head_b,
                      images, labels):
    p2 = dict(params)
    p2["head.w"], p2["head.b"] = head_w, head_b
    logits = M.forward(cfg, p2, images, adapters=adapters)
    loss = M.cross_entropy(logits, labels) * images.shape[0]
    return loss, M.n_correct(logits, labels), M.topk_correct(logits, labels, 5)
