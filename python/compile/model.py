"""L2: the ViT backbone (paper's ViT-B/16 family, scaled configs) in JAX.

The forward path routes every linear layer through the L1 tiled_matmul
Pallas kernel, so the AOT-lowered HLO exercises the kernels end to end.

Param layout is an explicit ordered spec (`param_specs`) — the single source
of truth shared with the Rust side via `manifest.json`: flat argument order
of every AOT artifact follows this list exactly.

Calibration mode additionally returns, for every *masked* (2-D weight)
tensor, the squared column norms of its input activations over the batch
(Alg. 1 steps 1-2); the Rust coordinator accumulates these across batches
and takes the sqrt inside its importance computation.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from .kernels import activation_colnorm_sq, linear


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    """Scaled ViT family. `micro` is the test/bench workhorse; `tiny` the
    e2e driver; `small` the largest AOT-able-in-CI config."""

    name: str
    image_size: int
    patch_size: int
    dim: int
    depth: int
    heads: int
    mlp_ratio: int
    num_classes: int
    channels: int = 3
    prompt_len: int = 8      # VPT baseline
    adapter_dim: int = 8     # Adapter baseline
    lora_rank: int = 8       # LoRA / sparse-LoRA

    @property
    def n_patches(self) -> int:
        return (self.image_size // self.patch_size) ** 2

    @property
    def seq_len(self) -> int:
        return self.n_patches + 1  # + cls token

    @property
    def patch_dim(self) -> int:
        return self.patch_size * self.patch_size * self.channels

    @property
    def mlp_dim(self) -> int:
        return self.dim * self.mlp_ratio

    @property
    def head_dim(self) -> int:
        assert self.dim % self.heads == 0
        return self.dim // self.heads


CONFIGS: dict[str, ViTConfig] = {
    "micro": ViTConfig("micro", image_size=16, patch_size=4, dim=64, depth=2,
                       heads=2, mlp_ratio=2, num_classes=32),
    "tiny": ViTConfig("tiny", image_size=32, patch_size=4, dim=128, depth=4,
                      heads=4, mlp_ratio=4, num_classes=32),
    "small": ViTConfig("small", image_size=32, patch_size=4, dim=192, depth=6,
                       heads=6, mlp_ratio=4, num_classes=64),
}


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple[int, ...]
    init: str          # "trunc_normal" | "zeros" | "ones"
    masked: bool       # True for 2-D weight matrices subject to Alg. 1
    # name of the activation statistic this weight's input contributes to
    stat: str | None = None


def param_specs(cfg: ViTConfig) -> list[ParamSpec]:
    """Ordered parameter layout. Rust mirrors this via the manifest."""
    specs: list[ParamSpec] = [
        ParamSpec("patch_embed.w", (cfg.patch_dim, cfg.dim), "trunc_normal",
                  True, "patch_embed.in"),
        ParamSpec("patch_embed.b", (cfg.dim,), "zeros", False),
        ParamSpec("cls_token", (1, cfg.dim), "trunc_normal", False),
        ParamSpec("pos_embed", (cfg.seq_len, cfg.dim), "trunc_normal", False),
    ]
    for i in range(cfg.depth):
        p = f"block{i}."
        specs += [
            ParamSpec(p + "ln1.scale", (cfg.dim,), "ones", False),
            ParamSpec(p + "ln1.bias", (cfg.dim,), "zeros", False),
            ParamSpec(p + "attn.qkv.w", (cfg.dim, 3 * cfg.dim), "trunc_normal",
                      True, p + "attn.qkv.in"),
            ParamSpec(p + "attn.qkv.b", (3 * cfg.dim,), "zeros", False),
            ParamSpec(p + "attn.proj.w", (cfg.dim, cfg.dim), "trunc_normal",
                      True, p + "attn.proj.in"),
            ParamSpec(p + "attn.proj.b", (cfg.dim,), "zeros", False),
            ParamSpec(p + "ln2.scale", (cfg.dim,), "ones", False),
            ParamSpec(p + "ln2.bias", (cfg.dim,), "zeros", False),
            ParamSpec(p + "mlp.fc1.w", (cfg.dim, cfg.mlp_dim), "trunc_normal",
                      True, p + "mlp.fc1.in"),
            ParamSpec(p + "mlp.fc1.b", (cfg.mlp_dim,), "zeros", False),
            ParamSpec(p + "mlp.fc2.w", (cfg.mlp_dim, cfg.dim), "trunc_normal",
                      True, p + "mlp.fc2.in"),
            ParamSpec(p + "mlp.fc2.b", (cfg.dim,), "zeros", False),
        ]
    specs += [
        ParamSpec("ln_f.scale", (cfg.dim,), "ones", False),
        ParamSpec("ln_f.bias", (cfg.dim,), "zeros", False),
        ParamSpec("head.w", (cfg.dim, cfg.num_classes), "trunc_normal",
                  True, "head.in"),
        ParamSpec("head.b", (cfg.num_classes,), "zeros", False),
    ]
    return specs


def masked_specs(cfg: ViTConfig) -> list[ParamSpec]:
    return [s for s in param_specs(cfg) if s.masked]


def stat_specs(cfg: ViTConfig) -> list[tuple[str, int]]:
    """(stat name, feature dim) for every calibration statistic, in the
    order the calibrate graph returns them — one per masked tensor, the
    feature dim being that tensor's d_in."""
    return [(s.stat, s.shape[0]) for s in masked_specs(cfg)]


def init_params(cfg: ViTConfig, key: jax.Array) -> dict[str, jax.Array]:
    params = {}
    for spec in param_specs(cfg):
        key, sub = jax.random.split(key)
        if spec.init == "zeros":
            params[spec.name] = jnp.zeros(spec.shape, jnp.float32)
        elif spec.init == "ones":
            params[spec.name] = jnp.ones(spec.shape, jnp.float32)
        else:  # trunc_normal, std = 0.02 like ViT
            params[spec.name] = 0.02 * jax.random.truncated_normal(
                sub, -2.0, 2.0, spec.shape, jnp.float32)
    return params


def num_params(cfg: ViTConfig) -> int:
    return sum(math.prod(s.shape) for s in param_specs(cfg))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6) * scale + bias


def patchify(cfg: ViTConfig, images: jax.Array) -> jax.Array:
    """(B, H, W, C) -> (B, n_patches, patch_dim)."""
    b = images.shape[0]
    p = cfg.patch_size
    g = cfg.image_size // p
    x = images.reshape(b, g, p, g, p, cfg.channels)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, g * g, cfg.patch_dim)


def _attention(cfg: ViTConfig, x: jax.Array, qkv_w, qkv_b, proj_w, proj_b,
               stats: dict | None):
    b, t, d = x.shape
    if stats is not None:
        stats["qkv.in"] = activation_colnorm_sq(x.reshape(b * t, d))
    qkv = linear(x, qkv_w, qkv_b)  # (b, t, 3d)
    qkv = qkv.reshape(b, t, 3, cfg.heads, cfg.head_dim)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
    q = q.transpose(0, 2, 1, 3)  # (b, h, t, hd)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(cfg.head_dim)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, d)
    if stats is not None:
        stats["proj.in"] = activation_colnorm_sq(out.reshape(b * t, d))
    return linear(out, proj_w, proj_b)


def _mlp(cfg: ViTConfig, x: jax.Array, fc1_w, fc1_b, fc2_w, fc2_b,
         stats: dict | None):
    b, t, d = x.shape
    if stats is not None:
        stats["fc1.in"] = activation_colnorm_sq(x.reshape(b * t, d))
    h = jax.nn.gelu(linear(x, fc1_w, fc1_b))
    if stats is not None:
        stats["fc2.in"] = activation_colnorm_sq(h.reshape(b * t, cfg.mlp_dim))
    return linear(h, fc2_w, fc2_b)


def forward(cfg: ViTConfig, params: dict[str, jax.Array], images: jax.Array,
            *, collect_stats: bool = False, prompt: jax.Array | None = None,
            adapters: dict[str, jax.Array] | None = None,
            deltas: dict[str, jax.Array] | None = None):
    """ViT forward.

    - collect_stats: also return {stat_name: colnorm_sq} (Alg. 1 step 1-2).
    - prompt: (prompt_len, dim) VPT tokens prepended after pos embedding.
    - adapters: {"block{i}.adapter.{down,up}.{w,b}"} bottleneck after MLP.
    - deltas: {masked tensor name: ΔW} added to the frozen weight (LoRA path).
    """
    stats: dict[str, jax.Array] | None = {} if collect_stats else None

    def wt(name: str) -> jax.Array:
        w = params[name]
        if deltas is not None and name in deltas:
            w = w + deltas[name]
        return w

    b = images.shape[0]
    patches = patchify(cfg, images)  # (b, np, pd)
    if stats is not None:
        stats["patch_embed.in"] = activation_colnorm_sq(
            patches.reshape(b * cfg.n_patches, cfg.patch_dim))
    x = linear(patches, wt("patch_embed.w"), params["patch_embed.b"])
    cls = jnp.broadcast_to(params["cls_token"], (b, 1, cfg.dim))
    x = jnp.concatenate([cls, x], axis=1) + params["pos_embed"][None]
    if prompt is not None:
        x = jnp.concatenate(
            [jnp.broadcast_to(prompt[None], (b,) + prompt.shape), x], axis=1)

    for i in range(cfg.depth):
        p = f"block{i}."
        bstats = {} if stats is not None else None
        h = _layer_norm(x, params[p + "ln1.scale"], params[p + "ln1.bias"])
        x = x + _attention(cfg, h, wt(p + "attn.qkv.w"),
                           params[p + "attn.qkv.b"], wt(p + "attn.proj.w"),
                           params[p + "attn.proj.b"], bstats)
        h = _layer_norm(x, params[p + "ln2.scale"], params[p + "ln2.bias"])
        mlp_out = _mlp(cfg, h, wt(p + "mlp.fc1.w"), params[p + "mlp.fc1.b"],
                       wt(p + "mlp.fc2.w"), params[p + "mlp.fc2.b"], bstats)
        if adapters is not None:
            a = jax.nn.gelu(linear(mlp_out, adapters[p + "adapter.down.w"],
                                   adapters[p + "adapter.down.b"]))
            mlp_out = mlp_out + linear(a, adapters[p + "adapter.up.w"],
                                       adapters[p + "adapter.up.b"])
        x = x + mlp_out
        if stats is not None:
            for k, val in bstats.items():
                prefix = "attn." if k.startswith(("qkv", "proj")) else "mlp."
                stats[p + prefix + k] = val

    x = _layer_norm(x, params["ln_f.scale"], params["ln_f.bias"])
    cls_idx = prompt.shape[0] if prompt is not None else 0
    cls_out = x[:, cls_idx, :]
    if stats is not None:
        stats["head.in"] = activation_colnorm_sq(cls_out)
    logits = linear(cls_out, wt("head.w"), params["head.b"])
    if stats is not None:
        return logits, stats
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=-1)
    return jnp.mean(nll)


def n_correct(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def topk_correct(logits: jax.Array, labels: jax.Array, k: int) -> jax.Array:
    """Rank-based top-k accuracy count.

    Deliberately avoids `lax.top_k`: jax >= 0.7 lowers it to the `topk` HLO
    custom op whose text syntax the xla_extension 0.5.1 parser (the version
    the `xla` crate links) rejects. rank(label) = #logits strictly greater
    lowers to plain compare+reduce ops that parse everywhere.
    """
    lab = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32), axis=-1)
    rank = jnp.sum((logits > lab).astype(jnp.int32), axis=-1)
    return jnp.sum((rank < k).astype(jnp.float32))
