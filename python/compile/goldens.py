"""Golden-vector generation: ref.py oracles -> JSON consumed by Rust tests.

The Rust `masking/` module re-implements importance scoring, per-neuron
top-K, N:M selection and the masked AdamW update (the coordinator needs
them host-side for allocation); these vectors pin the two implementations
to identical semantics, including top-k tie-breaking (lowest index wins).

Usage: python -m compile.goldens --out ../artifacts/goldens.json
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref


def _l(a) -> list:
    return np.asarray(a).tolist()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/goldens.json")
    args = ap.parse_args()

    key = jax.random.PRNGKey(42)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)

    w = jax.random.normal(k1, (8, 16), jnp.float32)
    x = jax.random.normal(k2, (32, 16), jnp.float32)
    g = jax.random.normal(k3, (8, 16), jnp.float32)
    m0 = 0.1 * jax.random.normal(k4, (8, 16), jnp.float32)
    v0 = jnp.abs(0.1 * jax.random.normal(k5, (8, 16), jnp.float32))

    colnorm_sq = ref.activation_colnorm_sq(x)
    scores = ref.importance_score(w, colnorm_sq)
    mask_k4 = ref.topk_row_mask(scores, 4)
    mask_nm = ref.nm_mask(scores, 2, 4)

    w1, m1, v1 = ref.masked_adam(w, g, mask_k4, m0, v0,
                                 lr=1e-2, beta1=0.9, beta2=0.999, eps=1e-8,
                                 wd=0.01, step=3.0)
    w_sgd, mom_sgd = ref.masked_sgd(w, g, mask_k4, m0,
                                    lr=1e-2, beta=0.9, wd=0.01)

    # Tie-breaking case: constant scores -> lowest indices win.
    ties = jnp.ones((3, 8), jnp.float32)
    mask_ties = ref.topk_row_mask(ties, 3)

    b = jax.random.normal(k1, (8, 4), jnp.float32)
    a = jax.random.normal(k2, (4, 16), jnp.float32)
    lora_delta = ref.masked_lora_delta(b, a, mask_k4, 2.0)

    goldens = {
        "w": _l(w), "x": _l(x), "g": _l(g), "m0": _l(m0), "v0": _l(v0),
        "colnorm_sq": _l(colnorm_sq),
        "scores": _l(scores),
        "mask_topk4": _l(mask_k4),
        "mask_nm_2_4": _l(mask_nm),
        "adam": {"lr": 1e-2, "beta1": 0.9, "beta2": 0.999, "eps": 1e-8,
                 "wd": 0.01, "step": 3.0,
                 "w1": _l(w1), "m1": _l(m1), "v1": _l(v1)},
        "sgd": {"lr": 1e-2, "beta": 0.9, "wd": 0.01,
                "w1": _l(w_sgd), "mom1": _l(mom_sgd)},
        "mask_ties_k3": _l(mask_ties),
        "lora": {"b": _l(b), "a": _l(a), "scale": 2.0,
                 "delta": _l(lora_delta)},
    }
    with open(args.out, "w") as f:
        json.dump(goldens, f)
    print(f"[goldens] wrote {args.out}")


if __name__ == "__main__":
    main()
