"""AOT pipeline: lower every L2 graph to HLO *text* + emit `manifest.json`.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every artifact's calling convention is flat: one HLO entry parameter per
tensor, ordered exactly as listed in the manifest `inputs`; outputs likewise.
Scalar hyperparameters (lr, wd, step) are rank-0 f32. The Rust runtime
(`rust/src/runtime/`) is driven entirely by the manifest — it never assumes
a layout beyond "param:NAME / mask:NAME / ..." name prefixes.

Usage:  python -m compile.aot --outdir ../artifacts [--configs micro,tiny]
                              [--batch 16] [--skip-variants]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dt(d) -> str:
    return {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}[jnp.dtype(d)]


class Io:
    """Accumulates the named flat input/output signature of one artifact."""

    def __init__(self):
        self.inputs: list[dict] = []
        self.outputs: list[dict] = []

    def inp(self, name, shape, dtype=F32):
        self.inputs.append(
            {"name": name, "shape": list(shape), "dtype": _dt(dtype)})
        return spec(shape, dtype)

    def out(self, name, shape, dtype=F32):
        self.outputs.append(
            {"name": name, "shape": list(shape), "dtype": _dt(dtype)})


# ---------------------------------------------------------------------------
# Per-artifact builders: return (flat_fn, input_specs, io)
# ---------------------------------------------------------------------------

def _param_group(io: Io, cfg, prefix: str):
    return [io.inp(f"{prefix}:{s.name}", s.shape) for s in M.param_specs(cfg)]


def _named(flat, specs_):
    return {s.name: a for s, a in zip(specs_, flat)}


def build_fwd(cfg, batch):
    io = Io()
    pspecs = M.param_specs(cfg)
    ins = _param_group(io, cfg, "param")
    ins.append(io.inp("images", (batch, cfg.image_size, cfg.image_size,
                                 cfg.channels)))
    io.out("logits", (batch, cfg.num_classes))

    def fn(*flat):
        params = _named(flat[:len(pspecs)], pspecs)
        return (T.forward_logits(cfg, params, flat[-1]),)

    return fn, ins, io


def build_eval(cfg, batch):
    io = Io()
    pspecs = M.param_specs(cfg)
    ins = _param_group(io, cfg, "param")
    ins.append(io.inp("images", (batch, cfg.image_size, cfg.image_size,
                                 cfg.channels)))
    ins.append(io.inp("labels", (batch,), I32))
    io.out("loss_sum", ())
    io.out("n_correct", ())
    io.out("top5_correct", ())

    def fn(*flat):
        params = _named(flat[:len(pspecs)], pspecs)
        return T.eval_step(cfg, params, flat[-2], flat[-1])

    return fn, ins, io


def build_calibrate(cfg, batch):
    io = Io()
    pspecs = M.param_specs(cfg)
    ins = _param_group(io, cfg, "param")
    ins.append(io.inp("images", (batch, cfg.image_size, cfg.image_size,
                                 cfg.channels)))
    for name, dim in M.stat_specs(cfg):
        io.out(f"stat:{name}", (dim,))

    def fn(*flat):
        params = _named(flat[:len(pspecs)], pspecs)
        return T.calibrate_step(cfg, params, flat[-1])

    return fn, ins, io


def build_grad_scores(cfg, batch):
    io = Io()
    pspecs = M.param_specs(cfg)
    ins = _param_group(io, cfg, "param")
    ins.append(io.inp("images", (batch, cfg.image_size, cfg.image_size,
                                 cfg.channels)))
    ins.append(io.inp("labels", (batch,), I32))
    for s in M.masked_specs(cfg):
        io.out(f"gradmag:{s.name}", s.shape)

    def fn(*flat):
        params = _named(flat[:len(pspecs)], pspecs)
        return T.grad_scores_step(cfg, params, flat[-2], flat[-1])

    return fn, ins, io


def build_train_adam(cfg, batch):
    io = Io()
    pspecs = M.param_specs(cfg)
    n = len(pspecs)
    ins = _param_group(io, cfg, "param")
    ins += _param_group(io, cfg, "mask")
    ins += _param_group(io, cfg, "adam_m")
    ins += _param_group(io, cfg, "adam_v")
    ins.append(io.inp("step", ()))
    ins.append(io.inp("images", (batch, cfg.image_size, cfg.image_size,
                                 cfg.channels)))
    ins.append(io.inp("labels", (batch,), I32))
    ins.append(io.inp("lr", ()))
    ins.append(io.inp("wd", ()))
    for s in pspecs:
        io.out(f"param:{s.name}", s.shape)
    for s in pspecs:
        io.out(f"adam_m:{s.name}", s.shape)
    for s in pspecs:
        io.out(f"adam_v:{s.name}", s.shape)
    io.out("loss", ())
    io.out("n_correct", ())
    io.out("top5_correct", ())

    def fn(*flat):
        params = _named(flat[0:n], pspecs)
        masks = _named(flat[n:2 * n], pspecs)
        m = _named(flat[2 * n:3 * n], pspecs)
        v = _named(flat[3 * n:4 * n], pspecs)
        step, images, labels, lr, wd = flat[4 * n:]
        np_, nm, nv, loss, nc, t5 = T.train_step_adam(
            cfg, params, masks, m, v, step, images, labels, lr, wd)
        outs = [np_[s.name] for s in pspecs]
        outs += [nm[s.name] for s in pspecs]
        outs += [nv[s.name] for s in pspecs]
        outs += [loss, nc, t5]
        return tuple(outs)

    return fn, ins, io


def build_train_sgd(cfg, batch):
    io = Io()
    pspecs = M.param_specs(cfg)
    n = len(pspecs)
    ins = _param_group(io, cfg, "param")
    ins += _param_group(io, cfg, "mask")
    ins += _param_group(io, cfg, "mom")
    ins.append(io.inp("images", (batch, cfg.image_size, cfg.image_size,
                                 cfg.channels)))
    ins.append(io.inp("labels", (batch,), I32))
    ins.append(io.inp("lr", ()))
    ins.append(io.inp("wd", ()))
    for s in pspecs:
        io.out(f"param:{s.name}", s.shape)
    for s in pspecs:
        io.out(f"mom:{s.name}", s.shape)
    io.out("loss", ())
    io.out("n_correct", ())

    def fn(*flat):
        params = _named(flat[0:n], pspecs)
        masks = _named(flat[n:2 * n], pspecs)
        moms = _named(flat[2 * n:3 * n], pspecs)
        images, labels, lr, wd = flat[3 * n:]
        np_, nmom, loss, nc = T.train_step_sgd(
            cfg, params, masks, moms, images, labels, lr, wd)
        outs = [np_[s.name] for s in pspecs]
        outs += [nmom[s.name] for s in pspecs]
        outs += [loss, nc]
        return tuple(outs)

    return fn, ins, io


def build_lora_train(cfg, batch):
    io = Io()
    pspecs = M.param_specs(cfg)
    lspecs = T.lora_target_specs(cfg)
    n, L, r = len(pspecs), len(lspecs), cfg.lora_rank
    ins = _param_group(io, cfg, "param")
    for s in lspecs:
        ins.append(io.inp(f"lora_b:{s.name}", (s.shape[0], r)))
    for s in lspecs:
        ins.append(io.inp(f"lora_a:{s.name}", (r, s.shape[1])))
    for s in lspecs:
        ins.append(io.inp(f"mask:{s.name}", s.shape))
    for grp, shape_of in (("mb", 0), ("vb", 0), ("ma", 1), ("va", 1)):
        for s in lspecs:
            shp = (s.shape[0], r) if shape_of == 0 else (r, s.shape[1])
            ins.append(io.inp(f"{grp}:{s.name}", shp))
    ins.append(io.inp("step", ()))
    ins.append(io.inp("images", (batch, cfg.image_size, cfg.image_size,
                                 cfg.channels)))
    ins.append(io.inp("labels", (batch,), I32))
    ins.append(io.inp("lr", ()))
    ins.append(io.inp("wd", ()))
    for grp, shape_of in (("lora_b", 0), ("lora_a", 1), ("mb", 0), ("vb", 0),
                          ("ma", 1), ("va", 1)):
        for s in lspecs:
            shp = (s.shape[0], r) if shape_of == 0 else (r, s.shape[1])
            io.out(f"{grp}:{s.name}", shp)
    io.out("loss", ())
    io.out("n_correct", ())
    io.out("top5_correct", ())

    def fn(*flat):
        i = 0

        def take(k):
            nonlocal i
            out = flat[i:i + k]
            i += k
            return out

        params = _named(take(n), pspecs)
        names = [s.name for s in lspecs]
        lb = dict(zip(names, take(L)))
        la = dict(zip(names, take(L)))
        masks = dict(zip(names, take(L)))
        mb = dict(zip(names, take(L)))
        vb = dict(zip(names, take(L)))
        ma = dict(zip(names, take(L)))
        va = dict(zip(names, take(L)))
        step, images, labels, lr, wd = take(5)
        nb, na, nmb, nvb, nma, nva, loss, nc, t5 = T.lora_train_step(
            cfg, params, lb, la, masks, mb, vb, ma, va, step, images, labels,
            lr, wd)
        outs = []
        for d in (nb, na, nmb, nvb, nma, nva):
            outs += [d[k] for k in names]
        outs += [loss, nc, t5]
        return tuple(outs)

    return fn, ins, io


def build_lora_eval(cfg, batch):
    io = Io()
    pspecs = M.param_specs(cfg)
    lspecs = T.lora_target_specs(cfg)
    n, L, r = len(pspecs), len(lspecs), cfg.lora_rank
    ins = _param_group(io, cfg, "param")
    for s in lspecs:
        ins.append(io.inp(f"lora_b:{s.name}", (s.shape[0], r)))
    for s in lspecs:
        ins.append(io.inp(f"lora_a:{s.name}", (r, s.shape[1])))
    for s in lspecs:
        ins.append(io.inp(f"mask:{s.name}", s.shape))
    ins.append(io.inp("images", (batch, cfg.image_size, cfg.image_size,
                                 cfg.channels)))
    ins.append(io.inp("labels", (batch,), I32))
    io.out("loss_sum", ())
    io.out("n_correct", ())
    io.out("top5_correct", ())

    def fn(*flat):
        params = _named(flat[:n], pspecs)
        names = [s.name for s in lspecs]
        lb = dict(zip(names, flat[n:n + L]))
        la = dict(zip(names, flat[n + L:n + 2 * L]))
        masks = dict(zip(names, flat[n + 2 * L:n + 3 * L]))
        images, labels = flat[n + 3 * L:]
        return T.lora_eval_step(cfg, params, lb, la, masks, images, labels)

    return fn, ins, io


def build_vpt_train(cfg, batch):
    io = Io()
    pspecs = M.param_specs(cfg)
    n = len(pspecs)
    hw_shape = (cfg.dim, cfg.num_classes)
    hb_shape = (cfg.num_classes,)
    pr_shape = (cfg.prompt_len, cfg.dim)
    tr_shapes = [pr_shape, hw_shape, hb_shape]
    tr_names = ["prompt", "head_w", "head_b"]
    ins = _param_group(io, cfg, "param")
    for nm_, sh in zip(tr_names, tr_shapes):
        ins.append(io.inp(nm_, sh))
    for grp in ("m", "v"):
        for nm_, sh in zip(tr_names, tr_shapes):
            ins.append(io.inp(f"{grp}:{nm_}", sh))
    ins.append(io.inp("step", ()))
    ins.append(io.inp("images", (batch, cfg.image_size, cfg.image_size,
                                 cfg.channels)))
    ins.append(io.inp("labels", (batch,), I32))
    ins.append(io.inp("lr", ()))
    ins.append(io.inp("wd", ()))
    for grp in ("", "m:", "v:"):
        for nm_, sh in zip(tr_names, tr_shapes):
            io.out(f"{grp}{nm_}", sh)
    io.out("loss", ())
    io.out("n_correct", ())
    io.out("top5_correct", ())

    def fn(*flat):
        params = _named(flat[:n], pspecs)
        prompt, hw, hb = flat[n:n + 3]
        m_state = tuple(flat[n + 3:n + 6])
        v_state = tuple(flat[n + 6:n + 9])
        step, images, labels, lr, wd = flat[n + 9:]
        ntr, nm, nv, loss, nc, t5 = T.vpt_train_step(
            cfg, params, prompt, hw, hb, m_state, v_state, step, images,
            labels, lr, wd)
        return (*ntr, *nm, *nv, loss, nc, t5)

    return fn, ins, io


def build_vpt_eval(cfg, batch):
    io = Io()
    pspecs = M.param_specs(cfg)
    n = len(pspecs)
    ins = _param_group(io, cfg, "param")
    ins.append(io.inp("prompt", (cfg.prompt_len, cfg.dim)))
    ins.append(io.inp("head_w", (cfg.dim, cfg.num_classes)))
    ins.append(io.inp("head_b", (cfg.num_classes,)))
    ins.append(io.inp("images", (batch, cfg.image_size, cfg.image_size,
                                 cfg.channels)))
    ins.append(io.inp("labels", (batch,), I32))
    io.out("loss_sum", ())
    io.out("n_correct", ())
    io.out("top5_correct", ())

    def fn(*flat):
        params = _named(flat[:n], pspecs)
        prompt, hw, hb, images, labels = flat[n:]
        return T.vpt_eval_step(cfg, params, prompt, hw, hb, images, labels)

    return fn, ins, io


def build_adapter_train(cfg, batch):
    io = Io()
    pspecs = M.param_specs(cfg)
    aspecs = T.adapter_specs(cfg)
    n, A = len(pspecs), len(aspecs)
    hw_shape = (cfg.dim, cfg.num_classes)
    hb_shape = (cfg.num_classes,)
    ins = _param_group(io, cfg, "param")
    for nm_, sh in aspecs:
        ins.append(io.inp(f"adapter:{nm_}", sh))
    ins.append(io.inp("head_w", hw_shape))
    ins.append(io.inp("head_b", hb_shape))
    for grp in ("m", "v"):
        for nm_, sh in aspecs:
            ins.append(io.inp(f"{grp}:adapter:{nm_}", sh))
        ins.append(io.inp(f"{grp}:head_w", hw_shape))
        ins.append(io.inp(f"{grp}:head_b", hb_shape))
    ins.append(io.inp("step", ()))
    ins.append(io.inp("images", (batch, cfg.image_size, cfg.image_size,
                                 cfg.channels)))
    ins.append(io.inp("labels", (batch,), I32))
    ins.append(io.inp("lr", ()))
    ins.append(io.inp("wd", ()))
    for grp in ("", "m:", "v:"):
        for nm_, sh in aspecs:
            io.out(f"{grp}adapter:{nm_}", sh)
        io.out(f"{grp}head_w", hw_shape)
        io.out(f"{grp}head_b", hb_shape)
    io.out("loss", ())
    io.out("n_correct", ())
    io.out("top5_correct", ())

    def fn(*flat):
        i = 0

        def take(k):
            nonlocal i
            out = flat[i:i + k]
            i += k
            return out

        params = _named(take(n), pspecs)
        names = [nm_ for nm_, _ in aspecs]
        ad = dict(zip(names, take(A)))
        hw, hb = take(2)
        m_ad = dict(zip(names, take(A)))
        m_hw, m_hb = take(2)
        v_ad = dict(zip(names, take(A)))
        v_hw, v_hb = take(2)
        step, images, labels, lr, wd = take(5)
        m_state = (m_ad, m_hw, m_hb)
        v_state = (v_ad, v_hw, v_hb)
        ntr, nm, nv, loss, nc, t5 = T.adapter_train_step(
            cfg, params, ad, hw, hb, m_state, v_state, step, images, labels,
            lr, wd)
        outs = []
        for tr in (ntr, nm, nv):
            tad, thw, thb = tr
            outs += [tad[k] for k in names]
            outs += [thw, thb]
        outs += [loss, nc, t5]
        return tuple(outs)

    return fn, ins, io


def build_adapter_eval(cfg, batch):
    io = Io()
    pspecs = M.param_specs(cfg)
    aspecs = T.adapter_specs(cfg)
    n, A = len(pspecs), len(aspecs)
    ins = _param_group(io, cfg, "param")
    for nm_, sh in aspecs:
        ins.append(io.inp(f"adapter:{nm_}", sh))
    ins.append(io.inp("head_w", (cfg.dim, cfg.num_classes)))
    ins.append(io.inp("head_b", (cfg.num_classes,)))
    ins.append(io.inp("images", (batch, cfg.image_size, cfg.image_size,
                                 cfg.channels)))
    ins.append(io.inp("labels", (batch,), I32))
    io.out("loss_sum", ())
    io.out("n_correct", ())
    io.out("top5_correct", ())

    def fn(*flat):
        params = _named(flat[:n], pspecs)
        names = [nm_ for nm_, _ in aspecs]
        ad = dict(zip(names, flat[n:n + A]))
        hw, hb, images, labels = flat[n + A:]
        return T.adapter_eval_step(cfg, params, ad, hw, hb, images, labels)

    return fn, ins, io


BUILDERS = {
    "fwd": build_fwd,
    "eval": build_eval,
    "calibrate": build_calibrate,
    "grad_scores": build_grad_scores,
    "train_adam": build_train_adam,
    "train_sgd": build_train_sgd,
    "lora_train": build_lora_train,
    "lora_eval": build_lora_eval,
    "vpt_train": build_vpt_train,
    "vpt_eval": build_vpt_eval,
    "adapter_train": build_adapter_train,
    "adapter_eval": build_adapter_eval,
}

CORE_KINDS = ["fwd", "eval", "calibrate", "grad_scores", "train_adam",
              "train_sgd"]
VARIANT_KINDS = ["lora_train", "lora_eval", "vpt_train", "vpt_eval",
                 "adapter_train", "adapter_eval"]


def config_manifest(cfg: M.ViTConfig) -> dict:
    return {
        "name": cfg.name,
        "image_size": cfg.image_size,
        "patch_size": cfg.patch_size,
        "dim": cfg.dim,
        "depth": cfg.depth,
        "heads": cfg.heads,
        "mlp_ratio": cfg.mlp_ratio,
        "num_classes": cfg.num_classes,
        "channels": cfg.channels,
        "prompt_len": cfg.prompt_len,
        "adapter_dim": cfg.adapter_dim,
        "lora_rank": cfg.lora_rank,
        "num_params": M.num_params(cfg),
        "params": [
            {"name": s.name, "shape": list(s.shape), "init": s.init,
             "masked": s.masked, "stat": s.stat}
            for s in M.param_specs(cfg)
        ],
        "lora_targets": [s.name for s in T.lora_target_specs(cfg)],
        "adapters": [{"name": nm_, "shape": list(sh)}
                     for nm_, sh in T.adapter_specs(cfg)],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--configs", default="micro,tiny")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--kinds", default=None,
                    help="comma list; default = core + variants")
    ap.add_argument("--skip-variants", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.outdir, exist_ok=True)
    kinds = (args.kinds.split(",") if args.kinds else
             CORE_KINDS + ([] if args.skip_variants else VARIANT_KINDS))

    manifest = {"version": 1, "batch": args.batch, "configs": {},
                "artifacts": []}
    for cname in args.configs.split(","):
        cfg = M.CONFIGS[cname]
        manifest["configs"][cname] = config_manifest(cfg)
        for kind in kinds:
            t0 = time.time()
            fn, ins, io = BUILDERS[kind](cfg, args.batch)
            # keep_unused: the manifest's flat calling convention must match
            # the HLO entry exactly even when a graph ignores a tensor
            # (e.g. calibrate never reads head.w).
            lowered = jax.jit(fn, keep_unused=True).lower(*ins)
            text = to_hlo_text(lowered)
            fname = f"{kind}_{cname}_b{args.batch}.hlo.txt"
            with open(os.path.join(args.outdir, fname), "w") as f:
                f.write(text)
            manifest["artifacts"].append({
                "name": f"{kind}_{cname}_b{args.batch}",
                "kind": kind,
                "config": cname,
                "batch": args.batch,
                "file": fname,
                "inputs": io.inputs,
                "outputs": io.outputs,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            })
            print(f"[aot] {fname}: {len(text)} chars, "
                  f"{len(io.inputs)} in / {len(io.outputs)} out, "
                  f"{time.time() - t0:.1f}s", flush=True)

    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
