"""L1 kernel for Eq. 6: ΔW = (B × A) ⊙ M — fused sparse low-rank delta.

The mask multiply is fused into the rank-expansion matmul so ΔW is written
to HBM exactly once (on real TPU the (bm, bn) output tile is masked while
still resident in VMEM). r is small (<= 64) so the full K dimension fits in
one block and no accumulator revisiting is needed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _lora_kernel(b_ref, a_ref, m_ref, s_ref, o_ref):
    delta = jnp.dot(b_ref[...], a_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = delta * s_ref[0, 0] * m_ref[...]


def _masked_lora_delta_raw(b: jax.Array, a: jax.Array, mask: jax.Array,
                           scale: float = 1.0) -> jax.Array:
    """b: (d1, r), a: (r, d2), mask: (d1, d2) -> ΔW (d1, d2) f32."""
    d1, r = b.shape
    r2, d2 = a.shape
    assert r == r2, (b.shape, a.shape)
    if mask.shape != (d1, d2):
        raise ValueError(f"mask shape {mask.shape} != ({d1}, {d2})")
    bm = common.pick_block(d1, 256)
    bn = common.pick_block(d2, common.LANE)
    grid = (d1 // bm, d2 // bn)
    s = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _lora_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((r, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d1, d2), jnp.float32),
        interpret=True,
    )(b, a, mask, s)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def masked_lora_delta(b: jax.Array, a: jax.Array, mask: jax.Array,
                      scale: float = 1.0) -> jax.Array:
    """Differentiable (w.r.t. b, a) Eq. 6 delta: (B × A) ⊙ M × scale."""
    return _masked_lora_delta_raw(b, a, mask, scale)


def _fwd(b, a, mask, scale):
    return _masked_lora_delta_raw(b, a, mask, scale), (b, a, mask)


def _bwd(scale, res, dout):
    b, a, mask = res
    dm = dout * mask * scale          # gradient through the mask gate
    db = jnp.dot(dm, a.T, preferred_element_type=jnp.float32)
    da = jnp.dot(b.T, dm, preferred_element_type=jnp.float32)
    return db, da, jnp.zeros_like(mask)


masked_lora_delta.defvjp(_fwd, _bwd)
