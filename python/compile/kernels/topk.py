"""L1 kernels for the paper's trainable-weight allocation (Alg. 1 step 3).

- ``topk_row_mask`` — per-neuron budget: each row of the score matrix keeps
  exactly its top-K entries. Rows are independent, so the grid tiles rows
  and each kernel instance sees full rows (d_in is small relative to VMEM:
  even ViT-B's 3072 f32 columns are 12 KiB/row).

- ``nm_mask`` — structured N:M selection within groups of M consecutive
  columns (sparse-tensor-core layout, DESIGN.md §6: M kept lane-aligned so
  groups never straddle (8,128) tiles on real hardware).

Exact-k selection uses `lax.top_k` index sets (deterministic tie-break:
lowest index wins), matched exactly by ref.py and by the Rust allocator in
`rust/src/masking/`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _topk_kernel(s_ref, o_ref, *, k: int):
    s = s_ref[...].astype(jnp.float32)
    d_in = s.shape[-1]
    _, idx = jax.lax.top_k(s, k)
    iota = jnp.arange(d_in, dtype=jnp.int32)[None, None, :]
    o_ref[...] = jnp.any(idx[..., None] == iota, axis=-2).astype(jnp.float32)


def topk_row_mask(s: jax.Array, k: int, *, block_rows: int | None = None) -> jax.Array:
    """s: (d_out, d_in) scores -> f32 mask with exactly min(k, d_in) ones/row."""
    d_out, d_in = s.shape
    k = min(int(k), d_in)
    if k <= 0:
        raise ValueError(f"k must be >= 1, got {k}")
    # Keep the (rows, k, d_in) one-hot intermediate under the VMEM budget.
    max_rows = max(1, common.VMEM_BUDGET // (4 * max(1, k) * d_in))
    br = block_rows or common.pick_block(d_out, min(64, max_rows))
    return pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        grid=(d_out // br,),
        in_specs=[pl.BlockSpec((br, d_in), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d_in), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((d_out, d_in), jnp.float32),
        interpret=True,
    )(s)


def _nm_kernel(s_ref, o_ref, *, n: int, m: int):
    s = s_ref[...].astype(jnp.float32)
    rows, d_in = s.shape
    g = s.reshape(rows, d_in // m, m)
    _, idx = jax.lax.top_k(g, n)
    iota = jnp.arange(m, dtype=jnp.int32)[None, None, None, :]
    mask = jnp.any(idx[..., None] == iota, axis=-2)
    o_ref[...] = mask.reshape(rows, d_in).astype(jnp.float32)


def nm_mask(s: jax.Array, n: int, m: int, *, block_rows: int | None = None) -> jax.Array:
    """Structured N:M mask: keep top-n of every m consecutive columns."""
    d_out, d_in = s.shape
    if d_in % m != 0:
        raise ValueError(f"d_in={d_in} not divisible by m={m}")
    if not 1 <= n <= m:
        raise ValueError(f"need 1 <= n <= m, got n={n} m={m}")
    br = block_rows or common.pick_block(d_out, 256)
    return pl.pallas_call(
        functools.partial(_nm_kernel, n=n, m=m),
        grid=(d_out // br,),
        in_specs=[pl.BlockSpec((br, d_in), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d_in), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((d_out, d_in), jnp.float32),
        interpret=True,
    )(s)
