"""L1 kernels for the paper's task-aware importance metric (Eq. 2).

Two kernels:

- ``activation_colnorm_sq`` — calibration statistics: per-feature sum of
  squared activations over tokens. Streamed over (token, feature) tiles;
  the (block_f,) accumulator stays resident in VMEM across the token grid
  dimension (revisiting pattern), so HBM traffic is read-once over X.

- ``importance_score`` — S = |W| ⊙ sqrt(colnorm_sq)[None, :]. Elementwise
  over W with the norm vector broadcast from a column-tile. VPU-bound,
  read-once over W.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _colnorm_kernel(x_ref, o_ref):
    # Grid is (features, tokens); token axis is innermost so the output
    # block for a given feature tile stays resident while we stream tokens.
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.sum(x * x, axis=0)


def activation_colnorm_sq(x: jax.Array, *, block_t: int | None = None,
                          block_f: int | None = None) -> jax.Array:
    """x: (T, F) -> (F,) sum over tokens of x^2 (f32)."""
    t_dim, f_dim = x.shape
    bt = block_t or common.pick_block(t_dim, 512)
    bf = block_f or common.pick_block(f_dim, common.LANE)
    grid = (f_dim // bf, t_dim // bt)
    return pl.pallas_call(
        _colnorm_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bt, bf), lambda f, t: (t, f))],
        out_specs=pl.BlockSpec((bf,), lambda f, t: (f,)),
        out_shape=jax.ShapeDtypeStruct((f_dim,), jnp.float32),
        interpret=True,
    )(x)


def _importance_kernel(w_ref, n_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)
    norms = jnp.sqrt(n_ref[...].astype(jnp.float32))
    o_ref[...] = jnp.abs(w) * norms[None, :]


def importance_score(w: jax.Array, colnorm_sq: jax.Array, *,
                     block_out: int | None = None,
                     block_in: int | None = None) -> jax.Array:
    """Eq. 2: S_ij = |W_ij| * ||X_j||_2 with colnorm_sq = ||X_j||_2^2.

    w: (d_out, d_in); colnorm_sq: (d_in,) -> S: (d_out, d_in) f32.
    """
    d_out, d_in = w.shape
    if colnorm_sq.shape != (d_in,):
        raise ValueError(
            f"colnorm_sq shape {colnorm_sq.shape} != ({d_in},) for w {w.shape}")
    bo = block_out or common.pick_block(d_out, 256)
    bi = block_in or common.pick_block(d_in, common.LANE)
    grid = (d_out // bo, d_in // bi)
    return pl.pallas_call(
        _importance_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bo, bi), lambda i, j: (i, j)),
            pl.BlockSpec((bi,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bo, bi), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d_out, d_in), jnp.float32),
        interpret=True,
    )(w, colnorm_sq)
