"""L1 kernels for the sparse optimizer step (Alg. 1 step 4).

W' = W - γ (∇W ⊙ M)  — plus the momentum / AdamW variants the paper's
experiments use. These are the per-step hot path of fine-tuning: purely
elementwise (VPU-bound on TPU), so the kernels fuse the mask multiply into
the optimizer arithmetic to read ∇W exactly once from HBM.

Moments are re-masked on every step so optimizer state is identically zero
off the trainable set (the paper's memory claim: state ∝ ||M||_0).

Scalars (lr, wd, step, ...) are passed as (1, 1) f32 blocks broadcast to the
tile — on real TPU these would live in SMEM; interpret mode does not care.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _as2d(x: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    shape = x.shape
    if x.ndim == 1:
        return x.reshape(1, -1), shape
    if x.ndim == 2:
        return x, shape
    return x.reshape(x.shape[0], -1), shape


def _scalar(v) -> jax.Array:
    return jnp.asarray(v, jnp.float32).reshape(1, 1)


def _blocks(shape: tuple[int, int]) -> tuple[int, int]:
    d0, d1 = shape
    return common.pick_block(d0, 256), common.pick_block(d1, common.LANE)


def _sgd_kernel(w_ref, g_ref, m_ref, mom_ref, lr_ref, beta_ref, wd_ref,
                w_out, mom_out):
    w = w_ref[...]
    mask = m_ref[...]
    lr, beta, wd = lr_ref[0, 0], beta_ref[0, 0], wd_ref[0, 0]
    gm = (g_ref[...] + wd * w) * mask
    mom_new = beta * mom_ref[...] + gm
    mom_out[...] = mom_new
    w_out[...] = w - lr * mom_new


def masked_sgd(w, g, mask, mom, lr, beta, wd):
    """Returns (w', mom'). All tensor args share a shape; scalars are python
    floats or 0-d arrays."""
    w2, orig = _as2d(w)
    g2, _ = _as2d(g)
    m2, _ = _as2d(mask)
    mom2, _ = _as2d(mom)
    b0, b1 = _blocks(w2.shape)
    grid = (w2.shape[0] // b0, w2.shape[1] // b1)
    tile = pl.BlockSpec((b0, b1), lambda i, j: (i, j))
    scal = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    w_new, mom_new = pl.pallas_call(
        _sgd_kernel,
        grid=grid,
        in_specs=[tile, tile, tile, tile, scal, scal, scal],
        out_specs=[tile, tile],
        out_shape=[jax.ShapeDtypeStruct(w2.shape, jnp.float32)] * 2,
        interpret=True,
    )(w2, g2, m2, mom2, _scalar(lr), _scalar(beta), _scalar(wd))
    return w_new.reshape(orig), mom_new.reshape(orig)


def _adam_kernel(w_ref, g_ref, mask_ref, m_ref, v_ref,
                 lr_ref, b1_ref, b2_ref, eps_ref, wd_ref, step_ref,
                 w_out, m_out, v_out):
    w = w_ref[...]
    mask = mask_ref[...]
    lr, b1, b2 = lr_ref[0, 0], b1_ref[0, 0], b2_ref[0, 0]
    eps, wd, step = eps_ref[0, 0], wd_ref[0, 0], step_ref[0, 0]
    gm = g_ref[...] * mask
    m_new = (b1 * m_ref[...] + (1.0 - b1) * gm) * mask
    v_new = (b2 * v_ref[...] + (1.0 - b2) * gm * gm) * mask
    mhat = m_new / (1.0 - jnp.power(b1, step))
    vhat = v_new / (1.0 - jnp.power(b2, step))
    upd = (mhat / (jnp.sqrt(vhat) + eps) + wd * w) * mask
    w_out[...] = w - lr * upd
    m_out[...] = m_new
    v_out[...] = v_new


def masked_adam(w, g, mask, m, v, lr, beta1, beta2, eps, wd, step):
    """AdamW on the masked support. `step` is the 1-based post-update count.

    Returns (w', m', v')."""
    w2, orig = _as2d(w)
    g2, _ = _as2d(g)
    mask2, _ = _as2d(mask)
    m2, _ = _as2d(m)
    v2, _ = _as2d(v)
    b0, b1blk = _blocks(w2.shape)
    grid = (w2.shape[0] // b0, w2.shape[1] // b1blk)
    tile = pl.BlockSpec((b0, b1blk), lambda i, j: (i, j))
    scal = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    w_new, m_new, v_new = pl.pallas_call(
        _adam_kernel,
        grid=grid,
        in_specs=[tile] * 5 + [scal] * 6,
        out_specs=[tile] * 3,
        out_shape=[jax.ShapeDtypeStruct(w2.shape, jnp.float32)] * 3,
        interpret=True,
    )(w2, g2, mask2, m2, v2, _scalar(lr), _scalar(beta1), _scalar(beta2),
      _scalar(eps), _scalar(wd), _scalar(step))
    return w_new.reshape(orig), m_new.reshape(orig), v_new.reshape(orig)
