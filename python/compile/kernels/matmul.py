"""MXU-tiled matmul kernel used by the ViT linear layers (L2 calls this).

Grid is (M/bm, N/bn, K/bk) with K innermost: the (bm, bn) f32 accumulator
block stays resident in VMEM across the K sweep (revisiting output pattern),
x/w blocks stream HBM->VMEM. On real TPU the blocks are 128^3 (full systolic
tiles, bf16 in / f32 acc); test configs degrade to exact divisors.

Wrapped in `jax.custom_vjp` so the whole ViT fwd/bwd lowers through the same
kernel: dx = dout @ w.T and dw = x.T @ dout are themselves tiled_matmul
calls (transposes are free at trace time — they fold into the HLO).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common

# L2 lowering switch (set by aot.py --fused): route linears through a plain
# XLA dot instead of the interpret-mode Pallas kernel. The Pallas path is
# the correctness/TPU-structure target; interpret-mode lowers its grid to
# HLO while-loops with dynamic slices, which the CPU backend executes far
# slower than a fused native dot (measured in EXPERIMENTS.md §Perf). Both
# artifact flavors are numerically identical (pytest pins them together).
USE_PALLAS = os.environ.get("TASKEDGE_FUSED_MATMUL", "0") != "1"


def _matmul_kernel(x_ref, w_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _tiled_matmul_raw(x: jax.Array, w: jax.Array) -> jax.Array:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bk, bn = common.matmul_blocks(m, k, n)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


@jax.custom_vjp
def tiled_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """(M, K) @ (K, N) -> (M, N), f32 accumulate, differentiable."""
    return _tiled_matmul_raw(x, w)


def _fwd(x, w):
    return _tiled_matmul_raw(x, w), (x, w)


def _bwd(res, dout):
    x, w = res
    dx = _tiled_matmul_raw(dout, w.T)
    dw = _tiled_matmul_raw(x.T, dout)
    return dx, dw


tiled_matmul.defvjp(_fwd, _bwd)


def linear(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    """Batched linear over arbitrary leading dims via the tiled kernel
    (or a native dot when TASKEDGE_FUSED_MATMUL=1 — see USE_PALLAS)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = tiled_matmul(x2, w) if USE_PALLAS else \
        jnp.dot(x2, w, preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b[None, :]
    return y.reshape(*lead, w.shape[-1])
