"""Shared helpers for the Pallas kernels.

All kernels in this package run under ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, so interpret mode is the correctness
target and real-TPU efficiency is estimated analytically (DESIGN.md §6/§7).

Block-size selection keeps the TPU layout discipline anyway (lane dim = 128,
sublane = 8 for f32) so the same BlockSpecs would be MXU/VPU-friendly when
compiled for real hardware.
"""

from __future__ import annotations

import functools

LANE = 128
SUBLANE = 8

# Soft VMEM budget per kernel invocation (bytes). Block shapes are chosen so
# that all resident blocks fit comfortably below this (real TPU v4 cores have
# ~16 MiB VMEM; we target <= 4 MiB so double-buffering still fits).
VMEM_BUDGET = 4 * 1024 * 1024


def pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is <= preferred.

    Pallas blocks must tile the array exactly (we never rely on implicit
    padding so interpret-mode and compiled-mode agree bit-for-bit).
    """
    if dim <= 0:
        raise ValueError(f"dim must be positive, got {dim}")
    b = min(dim, preferred)
    while dim % b != 0:
        b -= 1
    return b


def grid_dims(shape: tuple[int, ...], blocks: tuple[int, ...]) -> tuple[int, ...]:
    assert len(shape) == len(blocks)
    for s, b in zip(shape, blocks):
        if s % b != 0:
            raise ValueError(f"block {b} does not divide dim {s}")
    return tuple(s // b for s, b in zip(shape, blocks))


@functools.lru_cache(maxsize=None)
def matmul_blocks(m: int, k: int, n: int) -> tuple[int, int, int]:
    """MXU-oriented (bm, bk, bn) tile for an (m,k)x(k,n) matmul.

    Prefers 128x128x128 (full systolic-array tiles); degrades to exact
    divisors for the small research configs used in tests.
    """
    bm = pick_block(m, LANE)
    bk = pick_block(k, LANE)
    bn = pick_block(n, LANE)
    return bm, bk, bn


def vmem_bytes(*block_shapes: tuple[int, ...], dtype_bytes: int = 4) -> int:
    """Analytic VMEM footprint of a set of resident blocks."""
    total = 0
    for shp in block_shapes:
        n = 1
        for d in shp:
            n *= d
        total += n * dtype_bytes
    return total
