"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: ``python/tests/`` asserts each kernel
allclose against its oracle under hypothesis-driven shape/dtype sweeps, and
the Rust `masking/` module is validated against vectors generated from these
functions (see `python/compile/goldens.py`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def activation_colnorm_sq(x: jax.Array) -> jax.Array:
    """Sum over tokens of x^2 per input feature.  x: (T, F) -> (F,)."""
    return jnp.sum(x.astype(jnp.float32) ** 2, axis=0)


def importance_score(w: jax.Array, colnorm_sq: jax.Array) -> jax.Array:
    """Eq. 2 of the paper: S_ij = |W_ij| * ||X_j||_2.

    w: (d_out, d_in); colnorm_sq: (d_in,) is the *squared* column norm
    accumulated by `activation_colnorm_sq` (possibly over many batches);
    the sqrt happens here so accumulation stays a plain sum.
    """
    return jnp.abs(w) * jnp.sqrt(colnorm_sq)[None, :]


def topk_row_mask(s: jax.Array, k: int) -> jax.Array:
    """Alg. 1 step 3: per output neuron (row), mark the top-k scores.

    Exact-k selection with index tie-breaking (lower index wins), matching
    `lax.top_k` semantics. Returns f32 mask with exactly min(k, d_in) ones
    per row.
    """
    d_in = s.shape[-1]
    k = min(k, d_in)
    _, idx = jax.lax.top_k(s, k)
    iota = jnp.arange(d_in)[None, None, :]
    return jnp.any(idx[..., None] == iota, axis=-2).astype(jnp.float32)


def nm_mask(s: jax.Array, n: int, m: int) -> jax.Array:
    """Structured N:M selection: within each group of m consecutive weights
    along the input dim, keep the n with the highest scores."""
    d_out, d_in = s.shape
    if d_in % m != 0:
        raise ValueError(f"d_in={d_in} not divisible by group size m={m}")
    g = s.reshape(d_out, d_in // m, m)
    _, idx = jax.lax.top_k(g, n)
    iota = jnp.arange(m)[None, None, None, :]
    mask = jnp.any(idx[..., None] == iota, axis=-2)
    return mask.reshape(d_out, d_in).astype(jnp.float32)


def masked_sgd(w, g, mask, mom, lr, beta, wd):
    """Alg. 1 step 4 with momentum: W <- W - lr * (beta*mom + (g + wd*W) ⊙ M)."""
    gm = (g + wd * w) * mask
    mom_new = beta * mom + gm
    w_new = w - lr * mom_new
    return w_new, mom_new


def masked_adam(w, g, mask, m, v, lr, beta1, beta2, eps, wd, step):
    """AdamW restricted to the masked coordinates.

    Moments live only on trainable coordinates (m,v stay zero elsewhere) —
    this is the memory argument of the paper: optimizer state ∝ ||M||_0.
    `step` is the 1-based step count *after* this update.
    """
    gm = g * mask
    m_new = (beta1 * m + (1.0 - beta1) * gm) * mask
    v_new = (beta2 * v + (1.0 - beta2) * gm * gm) * mask
    mhat = m_new / (1.0 - beta1**step)
    vhat = v_new / (1.0 - beta2**step)
    upd = (mhat / (jnp.sqrt(vhat) + eps) + wd * w) * mask
    w_new = w - lr * upd
    return w_new, m_new, v_new


def masked_lora_delta(b: jax.Array, a: jax.Array, mask: jax.Array, scale: float = 1.0):
    """Eq. 6: ΔW = (B × A) ⊙ M (times LoRA scale α/r)."""
    return (b @ a) * scale * mask


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x, w, preferred_element_type=jnp.float32)
