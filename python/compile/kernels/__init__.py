"""L1: Pallas kernels for TaskEdge's compute hot-spots.

Every kernel has a pure-jnp oracle in `ref.py`; `python/tests/` asserts
allclose under hypothesis shape sweeps. All kernels run interpret=True
(CPU correctness target — see DESIGN.md §3/§6 for the real-TPU mapping).
"""

from .importance import activation_colnorm_sq, importance_score
from .lora import masked_lora_delta
from .masked_update import masked_adam, masked_sgd
from .matmul import linear, tiled_matmul
from .topk import nm_mask, topk_row_mask

__all__ = [
    "activation_colnorm_sq",
    "importance_score",
    "masked_lora_delta",
    "masked_adam",
    "masked_sgd",
    "linear",
    "tiled_matmul",
    "nm_mask",
    "topk_row_mask",
]
